"""Measured-latency control plane (ISSUE 10): sketches, level, service path.

Three suites:

* ``P2QuantileBank`` — the batched P² estimator's contracts: quantile
  accuracy against ``np.quantile`` on held streams, fixed-size state
  whatever the stream length, and the count-weighted merge (commutative,
  associative to within sketch tolerance, consistent with pooling).
* ``LinkSketchBank`` / ``LatencySLOScheduler`` — quarantine and staleness
  semantics, calibration refusal on half-empty banks, and the level's
  vet/premask/relax contract: calibrated budgets only ever *tighten* the
  static constant, the inert fallback reproduces the static region
  contract, and maintenance relax uses the measured tail ratio.
* service latency path — ``LatencyDelta`` is a non-structural signal: the
  shadow marks breaching apps dirty without raising ``capacity_dirty``,
  and a latency-SLO breach lets the drift detector's delta branch fire on
  a perfectly balanced fleet.
"""

import dataclasses
import types

import numpy as np
import pytest

from repro.core import generate_cluster
from repro.core.health import HealthConfig
from repro.core.levels import (
    Proposal,
    REGION_LATENCY_BUDGET_MS,
    RELAX_LATENCY_FACTOR,
)
from repro.netlat import (
    LatencySLOScheduler,
    LinkMeasurementSource,
    LinkSketchBank,
    NetlatConfig,
    P2QuantileBank,
    SourceConfig,
)
from repro.service import LatencyDelta, ServiceLoop
from repro.service.drift import DELTA, NOOP, DriftDetector
from repro.service.shadow import FleetShadow

# ---------------------------------------------------------------------------
# P² quantile bank
# ---------------------------------------------------------------------------


def _feed(bank, samples):
    for s in samples:
        bank.update(np.asarray(s).reshape(bank.shape))


def test_p2_quantile_accuracy_vs_numpy():
    """A single long stream: the sketch's p50/p99 land within a few
    percent of the exact empirical quantiles."""
    rng = np.random.default_rng(0)
    samples = rng.lognormal(3.0, 0.25, size=4000)
    bank = P2QuantileBank((1,))
    _feed(bank, samples)
    for p, tol in ((0.5, 0.03), (0.99, 0.06)):
        est = float(bank.quantile(p)[0])
        exact = float(np.quantile(samples, p))
        assert abs(est - exact) <= tol * exact, (p, est, exact)


def test_p2_batched_streams_are_independent():
    """A [2, 2] grid of scaled copies of one base stream: every stream's
    estimate is the base estimate scaled — one update call per grid
    observation, no cross-stream leakage."""
    rng = np.random.default_rng(1)
    base = rng.lognormal(2.0, 0.2, size=1500)
    scale = np.array([[1.0, 2.0], [5.0, 0.5]])
    bank = P2QuantileBank((2, 2))
    _feed(bank, [b * scale for b in base])
    med = bank.quantile(0.5)
    ref = float(np.quantile(base, 0.5))
    assert np.allclose(med, ref * scale, rtol=0.05), med


def test_p2_state_is_fixed_size():
    """No sample retention: the state arrays keep their shapes (and the
    buffer its five slots) from observation 10 to observation 10_000."""
    bank = P2QuantileBank((3, 3))
    rng = np.random.default_rng(2)
    _feed(bank, rng.uniform(1.0, 50.0, size=(10, 3, 3)))
    shapes = {k: getattr(bank, k).shape for k in ("heights", "pos", "desired", "count", "_buf")}
    _feed(bank, rng.uniform(1.0, 50.0, size=(10_000, 3, 3)))
    for k, shape in shapes.items():
        assert getattr(bank, k).shape == shape, k
    assert int(bank.count.min()) == 10_010


def test_p2_empirical_phase_answers_exactly_and_empty_is_nan():
    bank = P2QuantileBank((1,))
    assert np.isnan(bank.quantile(0.5)[0])
    xs = [4.0, 1.0, 9.0]
    _feed(bank, xs)
    assert float(bank.quantile(0.5)[0]) == pytest.approx(np.quantile(xs, 0.5))


def test_p2_merge_commutative_and_pool_consistent():
    rng = np.random.default_rng(3)
    sa = rng.lognormal(3.0, 0.3, size=1200)
    sb = rng.lognormal(3.2, 0.3, size=800)
    a, b = P2QuantileBank((1,)), P2QuantileBank((1,))
    _feed(a, sa)
    _feed(b, sb)
    ab, ba = a.merge(b), b.merge(a)
    assert int(ab.count[0]) == sa.size + sb.size
    for p in (0.5, 0.99):
        assert float(ab.quantile(p)[0]) == pytest.approx(float(ba.quantile(p)[0]), rel=1e-9)
        pooled = float(np.quantile(np.concatenate([sa, sb]), p))
        assert float(ab.quantile(p)[0]) == pytest.approx(pooled, rel=0.08)


def test_p2_merge_associative_within_tolerance():
    """(a + b) + c vs a + (b + c): identical marker probabilities queried,
    so the two orders agree to within the sketches' own approximation
    error — the mergeability contract per-shard probers rely on."""
    rng = np.random.default_rng(4)
    banks, streams = [], []
    for i in range(3):
        s = rng.lognormal(2.5 + 0.2 * i, 0.25, size=900)
        bank = P2QuantileBank((1,))
        _feed(bank, s)
        banks.append(bank)
        streams.append(s)
    a, b, c = banks
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    pooled = np.concatenate(streams)
    # The merge interpolates five CDF points per sketch, so the tail is
    # coarser than the body — hence the looser p99 accuracy bound.
    for p, tol in ((0.5, 0.06), (0.99, 0.15)):
        lq, rq = float(left.quantile(p)[0]), float(right.quantile(p)[0])
        exact = float(np.quantile(pooled, p))
        assert abs(lq - rq) <= 0.05 * exact, (p, lq, rq)
        assert abs(lq - exact) <= tol * exact, (p, lq, exact)


# ---------------------------------------------------------------------------
# link sketch bank: quarantine, staleness, calibration, health
# ---------------------------------------------------------------------------


def _warm_bank(num_regions=3, ticks=8, seed=5, base=20.0):
    bank = LinkSketchBank(num_regions)
    rng = np.random.default_rng(seed)
    lat = base * rng.uniform(0.5, 2.0, size=(num_regions, num_regions))
    for t in range(ticks):
        bank.ingest(lat * rng.uniform(0.95, 1.05, size=lat.shape), now=t)
    return bank, lat, ticks - 1


def test_bank_quarantines_implausible_samples():
    bank, lat, now = _warm_bank()
    before = bank.p99()
    bad = lat.copy()
    bad[0, 1] = np.nan
    bad[1, 0] = -3.0
    bad[2, 2] = lat[2, 2] * 50.0  # jump far beyond max_jump_factor x median
    n_bad = bank.ingest(bad, now=now + 1)
    assert n_bad == 3
    assert bank.quarantined_total >= 3
    # The poisoned entries never reached the sketch: estimates are stable.
    assert np.allclose(bank.p99(), before, rtol=0.05)
    # Quarantined-only pairs did not refresh their last_update stamp.
    assert bank.last_update[0, 1] == now
    assert bank.last_update[2, 2] == now


def test_bank_staleness_inflates_p99():
    bank, _, now = _warm_bank()
    cfg = HealthConfig()
    fresh = bank.p99(now)
    assert np.allclose(fresh, bank.p99(), rtol=1e-12)  # no inflation yet
    over = 4
    stale = bank.p99(now + cfg.stale_after + over)
    factor = min(cfg.max_inflation, (1.0 + cfg.uncertainty_growth) ** over)
    assert np.allclose(stale, fresh * factor, rtol=1e-9)
    blind = bank.p99(now + 10_000)
    assert np.allclose(blind, fresh * cfg.max_inflation, rtol=1e-9)


def test_bank_refuses_calibration_until_observed():
    bank = LinkSketchBank(3)
    assert not bank.calibrate(now=0)
    assert not bank.calibrated
    bank.ingest(np.full((3, 3), 10.0), now=0)  # 1 sample/pair: empirical
    assert not bank.observed
    assert not bank.calibrate(now=0)
    bank2, _, now = _warm_bank()
    assert bank2.observed
    assert bank2.calibrate(now)
    assert bank2.calibrated and bank2.calibrated_at == now
    assert np.isfinite(bank2.calibrated_p99).all()


def test_bank_relax_factor_is_measured_tail_ratio():
    bank = LinkSketchBank(2)
    assert bank.relax_factor() == RELAX_LATENCY_FACTOR  # unobserved default
    # A deliberately fat tail so the p999/p99 gap is visible to the sketch.
    source = LinkMeasurementSource(
        seed=9, config=SourceConfig(samples_per_tick=8, tail_prob=0.05, tail_factor=3.0)
    )
    lat = np.array([[1.0, 20.0], [20.0, 1.0]])
    for t in range(200):
        bank.ingest(source.measure(lat, t), now=t)
    f = bank.relax_factor(cap=2.5)
    assert 1.0 < f <= 2.5
    assert bank.relax_factor(cap=1.01) <= 1.01  # cap clips


def test_bank_signal_health_scores():
    bank, _, now = _warm_bank()
    cfg = HealthConfig()
    h = bank.signal_health(now)
    assert h.name == "link_latency" and h.score == 1.0
    assert bank.signal_health(now + cfg.blind_after + 1).score == 0.0


# ---------------------------------------------------------------------------
# the latency-SLO scheduler level
# ---------------------------------------------------------------------------


def _static_feasibility(cluster, budget=REGION_LATENCY_BUDGET_MS):
    """bool[N, T] the static region contract: every pair from the app's
    region to the tier's regions within the scalar budget."""
    lat = np.asarray(cluster.region_latency, np.float64)
    tiers = np.asarray(cluster.tier_regions, bool)
    worst = np.where(tiers[None, :, :], lat[:, None, :], -np.inf).max(axis=2)
    feas = worst[np.asarray(cluster.app_region)] <= budget
    feas[:, ~tiers.any(axis=1)] = False
    return feas


def _calibrated_bank(cluster, ticks=8, seed=21):
    lat = np.asarray(cluster.region_latency, np.float64)
    bank = LinkSketchBank(lat.shape[0])
    source = LinkMeasurementSource(seed=seed)
    for t in range(ticks):
        bank.ingest(source.measure(lat, t), now=t)
    assert bank.calibrate(ticks - 1)
    return bank, ticks - 1


def test_level_inert_fallback_matches_static_region_contract():
    cluster = generate_cluster(num_apps=48, seed=13)
    level = LatencySLOScheduler(cluster)  # no bank
    assert level.counters()["measured"] == 0
    feas = level.feasibility_matrix()
    assert np.array_equal(feas, _static_feasibility(cluster))
    assert np.array_equal(level.premask(cluster.problem), ~feas)


def test_level_calibrated_budgets_only_tighten_the_static_contract():
    cluster = generate_cluster(num_apps=48, seed=13)
    bank, now = _calibrated_bank(cluster)
    cfg = NetlatConfig()
    level = LatencySLOScheduler(cluster, bank=bank, config=cfg, now=now)
    assert level.counters()["measured"] == 1
    assert (level._budget <= cfg.cap_ms + 1e-9).all()
    assert (level._budget >= cfg.min_ms - 1e-9).all()
    # Measured feasibility is a subset of the static contract: nothing the
    # region level would veto is admitted by the measured budgets.
    assert not (level.feasibility_matrix() & ~_static_feasibility(cluster)).any()


def test_level_vet_rejects_budget_breaching_moves():
    cluster = generate_cluster(num_apps=48, seed=13)
    bank, now = _calibrated_bank(cluster)
    # Degrade one pair's live estimate far past any budget (but under the
    # plausibility jump limit — a real routing detour, not corruption):
    # every tier reachable through it becomes a measured no-go.
    degraded = np.asarray(cluster.region_latency, np.float64).copy()
    degraded[0, 1] *= 5.0
    for t in range(now + 1, now + 7):
        bank.ingest(LinkMeasurementSource(seed=3).measure(degraded, t), now=t)
    level = LatencySLOScheduler(cluster, bank=bank, now=now + 6)
    feas = level.feasibility_matrix()
    bad_tiers = np.where(np.asarray(cluster.tier_regions)[:, 1])[0]
    src0 = np.where(np.asarray(cluster.app_region) == 0)[0]
    assert bad_tiers.size and src0.size  # the fixture covers the arc
    assert not feas[np.ix_(src0, bad_tiers)].any()
    # vet: candidates into infeasible tiers come back rejected, feasible
    # ones pass, and the rejection counter advances.
    x0 = np.asarray(cluster.problem.assignment0).copy()
    n_bad, n_ok = int(src0[0]), None
    x = x0.copy()
    x[n_bad] = bad_tiers[0]
    for n in range(feas.shape[0]):
        ok_t = np.where(feas[n])[0]
        if n != n_bad and ok_t.size:
            n_ok, x[n] = n, ok_t[0]
            break
    rejected = level.vet(Proposal(x=x, x0=x0, candidates=np.array([n_bad, n_ok])))
    assert n_bad in rejected and n_ok not in rejected
    assert level.counters()["rejections"] == 1


def test_level_relax_uses_measured_tail_ratio():
    cluster = generate_cluster(num_apps=48, seed=13)
    bank, now = _calibrated_bank(cluster)
    level = LatencySLOScheduler(cluster, bank=bank, now=now)
    measured_factor = level._relax_factor
    assert measured_factor == pytest.approx(
        bank.relax_factor(cap=NetlatConfig().max_relax), abs=1e-9
    )
    x0 = np.asarray(cluster.problem.assignment0)
    relax_tiers = np.zeros(np.asarray(cluster.tier_regions).shape[0], bool)
    relax_tiers[x0[0]] = True
    plan = types.SimpleNamespace(relax_home_tiers=relax_tiers, relax_latency_factor=99.0)
    level.relax(plan, cluster)
    # Measured mode ignores the plan's declared factor; the relaxed apps
    # are exactly the residents of the drained tier.
    assert level._relax_factor == measured_factor != 99.0
    assert np.array_equal(level._relax_apps, relax_tiers[x0])
    # Uncalibrated level honors the declared factor (static parity).
    inert = LatencySLOScheduler(cluster)
    inert.relax(plan, cluster)
    assert inert._relax_factor == 99.0


# ---------------------------------------------------------------------------
# service latency path: LatencyDelta -> shadow -> drift
# ---------------------------------------------------------------------------


def test_latency_delta_is_not_structural():
    cluster = generate_cluster(num_apps=24, seed=3)
    shadow = FleetShadow(cluster)
    calm = np.asarray(cluster.region_latency, np.float64) * 0.5
    shadow.apply(LatencyDelta(region_latency=calm, collected_at=1), seq=1)
    assert not shadow.capacity_dirty
    assert not shadow.latency_breach
    assert not shadow.dirty_apps
    # The staged matrix is the delta's, not the cluster's original.
    assert np.allclose(shadow.view(1).region_latency, calm)


def test_latency_delta_breach_marks_apps_dirty_without_capacity_dirty():
    cluster = generate_cluster(num_apps=24, seed=3)
    shadow = FleetShadow(cluster)
    storm = np.asarray(cluster.region_latency, np.float64) * 10.0
    np.fill_diagonal(storm, 0.0)
    shadow.apply(LatencyDelta(region_latency=storm, collected_at=2), seq=1)
    assert shadow.latency_breach
    assert not shadow.capacity_dirty
    live = set(np.where(np.asarray(cluster.problem.valid))[0].tolist())
    assert shadow.dirty_apps == live
    for n in shadow.dirty_apps:
        assert shadow.applied_seq[n][-1] == 1
    shadow.clean()
    assert not shadow.latency_breach and not shadow.dirty_apps


def test_latency_breach_bypasses_the_delta_d2b_gate():
    det = DriftDetector()
    base = dict(
        loads=np.full(4, 0.4),
        capacity_dirty=False,
        outlook_active=False,
        stranded=0,
        dirty_shards=(1,),
        pending_membership=False,
        d2b=0.0,
    )
    calm = det.decide(now=0, **base)
    assert calm.action == NOOP
    breach = det.decide(now=1, latency_breach=True, **base)
    assert breach.action == DELTA
    assert breach.reason.startswith("latency-SLO breach")
    assert breach.dirty_shards == (1,)


def test_service_loop_latency_breach_triggers_delta_solve():
    cluster = generate_cluster(num_apps=24, seed=3)
    loop = ServiceLoop(cluster)
    loop.step(0)  # initial full pass; the fleet settles
    storm = np.asarray(cluster.region_latency, np.float64) * 10.0
    np.fill_diagonal(storm, 0.0)
    loop.submit(LatencyDelta(region_latency=storm, collected_at=1))
    out = loop.step(1)
    assert out.action == DELTA, (out.action, out.reason)
    assert "latency-SLO breach" in out.reason
    assert loop.dropped_events == 0
