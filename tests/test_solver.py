"""SPTLB solver behaviour: constraints hold, balance improves, engines agree.

Includes hypothesis property tests over random problem instances — the
solver must uphold the paper's hard constraints (§3.2.1 items 1-4) on every
input, not just the calibrated workload.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (GoalWeights, LocalSearchConfig, OptimalSearchConfig,
                        GreedyConfig, goal_terms, objective,
                        solve_greedy, solve_local, solve_optimal,
                        utilization_fraction, validate,
                        difference_to_balance)
from repro.core.problem import make_problem

# Real hypothesis when installed, deterministic fallback otherwise (tier-1
# must run without optional deps).
from _hypothesis_compat import hypothesis, st


# ---------------------------------------------------------------------------
# deterministic behaviour on the paper-calibrated workload
# ---------------------------------------------------------------------------

def test_local_search_improves_objective(cluster300):
    p = cluster300.problem
    res = solve_local(p, LocalSearchConfig(max_iters=256))
    assert res.objective < float(objective(p, p.assignment0))
    assert validate(p, res.assignment).ok


def test_local_search_respects_move_budget(cluster300):
    p = cluster300.problem
    res = solve_local(p, LocalSearchConfig(max_iters=10_000))
    assert res.num_moved <= int(p.move_budget)


def test_local_search_balances_all_three_objectives(cluster300):
    """Paper Fig. 3: SPTLB balances cpu, mem AND task count at once."""
    p = cluster300.problem
    res = solve_local(p, LocalSearchConfig(max_iters=256))
    uf, tf = utilization_fraction(p, res.assignment)
    uf0, tf0 = utilization_fraction(p, p.assignment0)
    def spread(a):
        return float(jnp.max(a) - jnp.min(a))
    for r in range(2):
        assert spread(uf[:, r]) < spread(uf0[:, r]) * 0.5
    assert spread(tf) < spread(tf0)


def test_greedy_balances_only_its_objective(cluster300):
    """Paper Fig. 3: each greedy variant balances only its own resource."""
    p = cluster300.problem
    uf0, tf0 = utilization_fraction(p, p.assignment0)
    def spread(a):
        return float(jnp.max(a) - jnp.min(a))

    res = solve_greedy(p, GreedyConfig(objective="cpu"))
    uf, tf = utilization_fraction(p, res.assignment)
    assert spread(uf[:, 0]) < spread(uf0[:, 0]) * 0.5   # cpu balanced
    # and at least one other objective is left clearly worse than SPTLB's
    sptlb = solve_local(p, LocalSearchConfig(max_iters=256))
    ufs, tfs = utilization_fraction(p, sptlb.assignment)
    assert (spread(uf[:, 1]) > spread(ufs[:, 1]) * 1.5
            or spread(tf) > spread(tfs) * 1.5)


def test_optimal_search_feasible_and_competitive(cluster300):
    p = cluster300.problem
    res = solve_optimal(p, OptimalSearchConfig(steps=300))
    assert validate(p, res.assignment).ok
    base = solve_local(p, LocalSearchConfig(max_iters=64))
    assert res.objective <= base.objective * 1.5


def test_sptlb_at_least_matches_best_greedy_on_worst_case_balance(cluster300):
    """SPTLB's worst-case balance is no worse than even the luckiest
    single-objective greedy variant (Fig. 3's multi-objective claim; a
    single greedy can tie by luck, hence the tolerance)."""
    p = cluster300.problem
    sptlb = solve_local(p, LocalSearchConfig(max_iters=256))
    best_greedy = min(
        difference_to_balance(p, solve_greedy(
            p, GreedyConfig(objective=o)).assignment)
        for o in ("cpu", "mem", "task"))
    assert (difference_to_balance(p, sptlb.assignment)
            <= best_greedy * 1.15 + 1e-6)


def test_goal_priority_permutation_changes_weights():
    w = GoalWeights.from_priority((
        "criticality", "movement_cost", "task_balance",
        "resource_balance", "under_ideal"))
    assert float(w.criticality) > float(w.under_ideal)


def test_solver_deterministic(cluster300):
    p = cluster300.problem
    r1 = solve_local(p, LocalSearchConfig(max_iters=128, seed=3))
    r2 = solve_local(p, LocalSearchConfig(max_iters=128, seed=3))
    assert np.array_equal(np.asarray(r1.assignment), np.asarray(r2.assignment))


def test_warm_start_respects_budget(cluster300):
    p = cluster300.problem
    first = solve_local(p, LocalSearchConfig(max_iters=64))
    res = solve_local(p, LocalSearchConfig(max_iters=64),
                      init_assignment=first.assignment)
    assert res.num_moved <= int(p.move_budget)
    assert validate(p, res.assignment).ok


# ---------------------------------------------------------------------------
# property-based invariants (hypothesis)
# ---------------------------------------------------------------------------

@st.composite
def problems(draw):
    N = draw(st.integers(8, 60))
    T = draw(st.integers(2, 6))
    S = 3
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    demand = rng.lognormal(0.5, 0.8, (N, 2)).astype(np.float32)
    tasks = rng.integers(1, 30, N).astype(np.float32)
    slo = rng.integers(0, S, N).astype(np.int32)
    crit = rng.random(N).astype(np.float32)
    slo_allowed = rng.random((T, S)) < 0.6
    slo_allowed[:, 0] = True                        # universal class
    for s in range(S):                              # every class placeable
        if not slo_allowed[:, s].any():
            slo_allowed[rng.integers(T), s] = True
    x0 = np.array([rng.choice(np.where(slo_allowed[:, s])[0])
                   for s in slo], np.int32)
    util0 = np.zeros((T, 2), np.float32)
    np.add.at(util0, x0, demand)
    cap = util0 * rng.uniform(1.1, 3.0, (T, 1)).astype(np.float32) \
        + demand.max(0) * 2
    tasks0 = np.zeros(T, np.float32)
    np.add.at(tasks0, x0, tasks)
    klim = tasks0 * 2 + tasks.max() * 2
    move_frac = draw(st.sampled_from([0.05, 0.1, 0.3]))
    return make_problem(demand=demand, tasks=tasks, slo=slo,
                        criticality=crit, assignment0=x0, capacity=cap,
                        task_limit=klim, slo_allowed=slo_allowed,
                        move_frac=move_frac)


@hypothesis.given(problems())
@hypothesis.settings(max_examples=15, deadline=None,
                     suppress_health_check=[hypothesis.HealthCheck.too_slow])
def test_property_local_search_always_feasible(p):
    res = solve_local(p, LocalSearchConfig(max_iters=64))
    v = validate(p, res.assignment)
    assert v.ok, v
    assert res.objective <= float(objective(p, p.assignment0)) + 1e-5


@hypothesis.given(problems())
@hypothesis.settings(max_examples=10, deadline=None,
                     suppress_health_check=[hypothesis.HealthCheck.too_slow])
def test_property_optimal_search_always_feasible(p):
    res = solve_optimal(p, OptimalSearchConfig(steps=60))
    assert validate(p, res.assignment).ok


@hypothesis.given(problems(), st.sampled_from(["cpu", "mem", "task"]))
@hypothesis.settings(max_examples=10, deadline=None,
                     suppress_health_check=[hypothesis.HealthCheck.too_slow])
def test_property_greedy_respects_budget_and_slo(p, obj):
    res = solve_greedy(p, GreedyConfig(objective=obj, max_steps=500))
    assert res.num_moved <= int(p.move_budget)
    x = np.asarray(res.assignment)
    x0 = np.asarray(p.assignment0)
    moved = x != x0
    allowed = np.asarray(p.slo_allowed)[x[moved], np.asarray(p.slo)[moved]]
    assert allowed.all()


@hypothesis.given(problems())
@hypothesis.settings(max_examples=10, deadline=None,
                     suppress_health_check=[hypothesis.HealthCheck.too_slow])
def test_property_goal_terms_nonnegative(p):
    terms = goal_terms(p, p.assignment0)
    for name, val in terms.items():
        assert float(val) >= -1e-6, name


def test_goal_priority_permutations_no_significant_change(cluster300):
    """Paper §3.2.1: "the explored results do not provide any significant
    improvements from the default priorities" — permuting goal priorities
    must not change solution quality much on the calibrated workload."""
    import dataclasses as _dc
    p = cluster300.problem
    base = solve_local(p, LocalSearchConfig(max_iters=256))
    d2b_base = difference_to_balance(p, base.assignment)
    for order in (("resource_balance", "under_ideal", "task_balance",
                   "movement_cost", "criticality"),
                  ("task_balance", "resource_balance", "under_ideal",
                   "movement_cost", "criticality")):
        p2 = _dc.replace(p, weights=GoalWeights.from_priority(order))
        res = solve_local(p2, LocalSearchConfig(max_iters=256))
        assert validate(p2, res.assignment).ok
        d2b = difference_to_balance(p2, res.assignment)
        assert abs(d2b - d2b_base) < 0.12, (order, d2b, d2b_base)
