"""Streaming substrate: determinism, seekability, routing, backpressure."""
import time

import numpy as np
import pytest

from repro.streams import (BackpressureError, Prefetcher, StreamConfig,
                           StreamRouter, TokenStream, build_cluster,
                           demo_apps)
from repro.launch.train import default_slices


def test_stream_deterministic_and_seekable():
    cfg = StreamConfig(vocab_size=512, seq_len=32, global_batch=8)
    a = TokenStream(cfg)
    b = TokenStream(cfg)
    for step in (0, 5, 1000):
        np.testing.assert_array_equal(a.batch(step)["tokens"],
                                      b.batch(step)["tokens"])
    # different steps differ
    assert not np.array_equal(a.batch(0)["tokens"], a.batch(1)["tokens"])


def test_stream_batch_shape_any_partition_count():
    for gb, parts in ((8, 16), (16, 5), (32, 32)):
        cfg = StreamConfig(vocab_size=128, seq_len=16, global_batch=gb,
                           num_partitions=parts)
        batch = TokenStream(cfg).batch(0)
        assert batch["tokens"].shape == (gb, 16)
        assert batch["targets"].shape == (gb, 16)


def test_targets_are_shifted_tokens():
    cfg = StreamConfig(vocab_size=128, seq_len=16, global_batch=4)
    s = TokenStream(cfg)
    raw = s.sample(0, 0)
    batch = s.batch(0)
    np.testing.assert_array_equal(batch["tokens"][0], raw[0, :-1])
    np.testing.assert_array_equal(batch["targets"][0], raw[0, 1:])


def test_prefetcher_produces_sequential_steps():
    cfg = StreamConfig(vocab_size=128, seq_len=8, global_batch=4, prefetch=2)
    pf = Prefetcher(TokenStream(cfg), start_step=0)
    steps = [next(pf)["_step"] for _ in range(4)]
    pf.close()
    assert steps == [0, 1, 2, 3]
    assert pf.stats.consumed == 4
    assert pf.stats.produced >= 4


def test_prefetcher_counts_stalls_and_keeps_pending_batch():
    # Tiny queue, fast stall clock, generous max_stalls: the worker must
    # stall (consumer drains nothing for a while), keep the pending batch,
    # and deliver every step exactly once when draining resumes.
    cfg = StreamConfig(vocab_size=64, seq_len=4, global_batch=2, prefetch=1,
                       stall_timeout_s=0.02, max_stalls=10_000)
    pf = Prefetcher(TokenStream(cfg), start_step=0)
    deadline = time.monotonic() + 5.0
    while pf.stats.stalls < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert pf.stats.stalls >= 3
    steps = [next(pf)["_step"] for _ in range(5)]
    pf.close()
    assert steps == [0, 1, 2, 3, 4]          # no step skipped or repeated
    assert pf.stats.max_stall_run >= 3
    assert pf.stats.dropped == pf.stats.produced - pf.stats.consumed


def test_prefetcher_raises_on_wedged_consumer():
    cfg = StreamConfig(vocab_size=64, seq_len=4, global_batch=2, prefetch=1,
                       stall_timeout_s=0.01, max_stalls=3)
    pf = Prefetcher(TokenStream(cfg), start_step=0)
    try:
        # Never consume: the worker trips max_stalls and parks the error.
        deadline = time.monotonic() + 5.0
        while pf._error is None and time.monotonic() < deadline:
            time.sleep(0.01)
        with pytest.raises(BackpressureError):
            next(pf)
        assert pf.stats.max_stall_run >= 3
    finally:
        pf.close()


def test_router_routes_apps_to_slices():
    apps = demo_apps(48, seed=0)
    cluster = build_cluster(apps, default_slices(), seed=0)
    router = StreamRouter(cluster)
    decision = router.route()
    assert decision.violations.ok
    # every app is assigned to exactly one tier; partitions follow it
    total = sum(len(router.partitions_for_tier(t, apps))
                for t in range(5))
    assert total == len(apps)
