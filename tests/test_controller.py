"""BalanceController multi-tick behaviour: cooldown, dry_run, audit
consistency, cluster swaps, the SLO-stranded trigger, and the restart
knob's never-worse contract (ISSUE 3 satellites)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import generate_cluster
from repro.core.controller import (BalanceController, ControllerConfig,
                                   TickInput)
from repro.service.events import AdvisoryBatch


def _tick(ctl, cluster=None, now=None, collected_at=None):
    """One control round via the typed API; returns the audit event."""
    return ctl.step(TickInput(cluster=cluster, now=now,
                              collected_at=collected_at)).event


@pytest.fixture()
def cluster():
    return generate_cluster(num_apps=150, seed=5)


def test_cooldown_suppresses_triggers_across_ticks(cluster):
    ctl = BalanceController(cluster, ControllerConfig(cooldown_rounds=4,
                                                      timeout_s=4))
    ev1 = _tick(ctl)
    assert ev1.applied
    for _ in range(3):                       # rounds 2..4 are inside cooldown
        ev = _tick(ctl)
        assert not ev.triggered and "cooldown" in ev.reason
    ev5 = _tick(ctl)                         # cooldown expired
    assert "cooldown" not in ev5.reason


def test_dry_run_never_mutates_across_ticks(cluster):
    before = np.asarray(cluster.problem.assignment0).copy()
    ctl = BalanceController(cluster, ControllerConfig(
        dry_run=True, cooldown_rounds=1, timeout_s=4))
    for _ in range(3):
        ev = _tick(ctl)
        assert not ev.applied
    np.testing.assert_array_equal(
        np.asarray(ctl.cluster.problem.assignment0), before)
    assert ctl.audit()["rebalances"] == 0
    assert ctl.audit()["total_moved"] == 0


def test_audit_totals_match_event_history(cluster):
    ctl = BalanceController(cluster, ControllerConfig(
        trigger_d2b=0.0, trigger_over_ideal=0.0, cooldown_rounds=1,
        timeout_s=4))
    for _ in range(4):
        _tick(ctl)
    audit = ctl.audit()
    applied = [e for e in ctl.history if e.applied]
    assert audit["rounds"] == len(ctl.history) == 4
    assert audit["rebalances"] == len(applied) >= 1
    assert audit["total_moved"] == sum(e.moved for e in applied)
    assert audit["mean_improvement"] == pytest.approx(
        float(np.mean([e.d2b_before - e.d2b_after for e in applied])))


def test_tick_accepts_externally_evolved_cluster(cluster):
    """The sim harness hands an evolved cluster to every tick; the reused
    balancer must re-sync before deciding."""
    ctl = BalanceController(cluster, ControllerConfig(timeout_s=4))
    _tick(ctl)
    evolved = dataclasses.replace(cluster)   # fresh telemetry stand-in
    _tick(ctl, evolved)
    # the controller may have applied a rebalance on top of the evolved
    # cluster — either way balancer and controller stay in lock-step
    assert ctl._sptlb.cluster is ctl.cluster
    # legacy path: direct assignment between ticks still re-syncs
    ctl.cluster = dataclasses.replace(ctl.cluster)
    _tick(ctl)
    assert ctl._sptlb.cluster is ctl.cluster


def test_slo_stranded_trigger(cluster):
    """Capacity events can strand incumbents on newly-ineligible tiers; the
    controller must react even when balance metrics alone would not."""
    p = cluster.problem
    x0 = np.asarray(p.assignment0)
    hot = int(np.bincount(x0).argmax())
    slo_allowed = np.asarray(p.slo_allowed).copy()
    slo_allowed[hot] = False
    stranded_cluster = dataclasses.replace(
        cluster, problem=dataclasses.replace(
            p, slo_allowed=jnp.asarray(slo_allowed)))
    quiet = dict(trigger_d2b=10.0, trigger_over_ideal=10.0, timeout_s=4)
    ctl = BalanceController(stranded_cluster,
                            ControllerConfig(**quiet, trigger_slo_apps=1))
    triggered, reason = ctl.should_rebalance()
    assert triggered and "slo-stranded" in reason
    # disabled check: the same cluster reads as balanced
    ctl_off = BalanceController(stranded_cluster,
                                ControllerConfig(**quiet,
                                                 trigger_slo_apps=None))
    triggered, reason = ctl_off.should_rebalance()
    assert not triggered and "balanced" in reason


def test_movement_budget_enforced_across_ticks(cluster):
    """The trajectory budget is hard: applied cost never exceeds it, the
    overruns are observable, and an exhausted budget blocks movement."""
    budget = 3.0
    ctl = BalanceController(cluster, ControllerConfig(
        trigger_d2b=0.0, trigger_over_ideal=0.0, cooldown_rounds=1,
        timeout_s=4, movement_cost_budget=budget))
    for _ in range(4):
        _tick(ctl)
    assert ctl.cost_spent <= budget + 1e-6
    audit = ctl.audit()
    assert audit["movement_cost"] <= budget + 1e-6
    assert audit["movement_cost_budget"] == budget
    assert audit["budget_overruns"] >= 1
    limited = [e for e in ctl.history if e.budget_limited]
    assert limited
    # Once exhausted, later triggered rounds are blocked, not silently free.
    exhausted = [e for e in ctl.history if "budget exhausted" in e.reason]
    if exhausted:
        assert all(not e.applied for e in exhausted)


def test_unbudgeted_controller_still_prices_movement(cluster):
    ctl = BalanceController(cluster, ControllerConfig(timeout_s=4))
    ev = _tick(ctl)
    assert ev.applied and ev.movement_cost > 0
    assert not ev.budget_limited
    assert ctl.audit()["budget_overruns"] == 0


QUIET = dict(trigger_d2b=10.0, trigger_over_ideal=10.0,
             trigger_slo_apps=None, timeout_s=4, cooldown_rounds=1)


def test_declared_event_never_fired_leaves_budget_untouched(cluster):
    """An advisory beyond the horizon must not trigger, move, or spend —
    declaring maintenance is free until the window approaches."""
    from repro.core.planner import CAPACITY, Advisory
    ctl = BalanceController(cluster, ControllerConfig(
        **QUIET, movement_cost_budget=50.0))
    ctl.ingest(AdvisoryBatch(advisories=(
        Advisory(at=10_000, kind=CAPACITY, tier=2, scale=0.05),)))
    for tick in range(3):
        ev = _tick(ctl, now=tick)
        assert not ev.triggered and ev.plan_pending == 0
    assert ctl.cost_spent == 0.0
    assert ctl.audit()["budget_overruns"] == 0
    assert ctl.audit()["rebalances"] == 0


def test_declared_drain_triggers_proactively_and_pre_evacuates(cluster):
    """With balance metrics quiet, a declared drain inside the horizon is
    the only trigger — and the controller starts emptying the tier before
    the event fires."""
    from repro.core.planner import CAPACITY, Advisory
    x0 = np.asarray(cluster.problem.assignment0)
    valid = np.asarray(cluster.problem.valid)
    hot = int(np.bincount(x0[valid]).argmax())
    before = int(((x0 == hot) & valid).sum())

    ctl = BalanceController(cluster, ControllerConfig(**QUIET))
    ctl.ingest(AdvisoryBatch(advisories=(
        Advisory(at=6, kind=CAPACITY, tier=hot, scale=0.05),)))
    events = [_tick(ctl, now=tick) for tick in range(4)]
    assert any(e.triggered and "declared-maintenance" in e.reason
               for e in events)
    assert any(e.applied for e in events)
    x = np.asarray(ctl.cluster.problem.assignment0)
    after = int(((x == hot) & valid).sum())
    assert after < before                      # evacuation began pre-event


def test_controller_restart_rounds_threads_through(cluster):
    """restart_rounds reaches the cooperation loop (the never-worse
    objective contract itself is asserted in test_hierarchy.py)."""
    ctl = BalanceController(cluster, ControllerConfig(timeout_s=4,
                                                      restart_rounds=2))
    ev = _tick(ctl)
    assert ev.triggered and ev.applied
    assert ev.d2b_after < ev.d2b_before
