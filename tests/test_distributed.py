"""Distribution substrate: sharding specs, checkpoint roundtrip, fault events,
optimizer behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as SH
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import (CapacityEvent, FaultInjector,
                                     degrade, rebalance)
from repro.core import generate_cluster
from repro.launch.mesh import make_host_mesh
from repro.models import build_model, reduce_for_smoke
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   lr_schedule)
from repro.train.train_step import init_train_state, make_train_step


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_param_specs_column_row():
    spec = SH.param_spec(("layers", 0, "attn", "wq"),
                         jax.ShapeDtypeStruct((42, 1024, 2048), jnp.float32))
    assert spec == P(None, None, "model")
    spec = SH.param_spec(("layers", 0, "attn", "wo"),
                         jax.ShapeDtypeStruct((42, 2048, 1024), jnp.float32))
    assert spec == P(None, "model", None)
    spec = SH.param_spec(("embed",),
                         jax.ShapeDtypeStruct((50304, 1024), jnp.float32))
    assert spec == P("model", None)


def test_moe_experts_are_expert_parallel():
    spec = SH.param_spec(("layers", 0, "moe", "w_gate"),
                         jax.ShapeDtypeStruct((24, 32, 1024, 512), jnp.float32))
    # stacked [L, E, d, f] -> expert axis sharded
    assert spec == P(None, "model", None, None)


def test_sanitize_drops_indivisible():
    mesh = make_host_mesh(data=1, model=1)
    spec = SH.sanitize(P(None, "model"), (10, 7), mesh)   # 7 % 1 == 0 -> kept
    assert spec == P(None, "model")


def test_full_tree_shardings_build():
    """Sharding specs build for every arch's full-size param tree."""
    mesh = make_host_mesh(data=1, model=1)
    for arch in ("gemma2-9b", "deepseek-v2-lite-16b", "zamba2-2.7b",
                 "xlstm-125m", "hubert-xlarge"):
        cfg = get_config(arch)
        model = build_model(cfg)
        abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        sh = SH.params_shardings(mesh, abs_params)
        assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(abs_params))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = reduce_for_smoke(get_config("smollm-360m"))
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(5, state)
    restored, step = mgr.restore(state)
    assert step == 5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"w": jnp.arange(8.0)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    tree = {"w": jnp.arange(128.0)}
    mgr.save(7, tree, blocking=False)
    mgr.wait()
    restored, step = mgr.restore(tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_rejects_shape_mismatch(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.zeros(4)})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros(8)})


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, {"w": jnp.zeros(4)})
    assert not list(tmp_path.glob("*.tmp"))


# ---------------------------------------------------------------------------
# fault tolerance -> SPTLB rebalance
# ---------------------------------------------------------------------------

def test_degrade_shrinks_capacity():
    cluster = generate_cluster(num_apps=100, seed=0)
    before = np.asarray(cluster.problem.capacity).copy()
    ev = CapacityEvent("host_failure", tier=2, fraction=0.25)
    after = degrade(cluster, ev.to_timed())
    np.testing.assert_allclose(np.asarray(after.problem.capacity)[2],
                               before[2] * 0.75, rtol=1e-6)
    assert after.hosts_per_tier[2] < cluster.hosts_per_tier[2]


def test_rebalance_after_failure_feasible_and_bounded():
    cluster = generate_cluster(num_apps=200, seed=1)
    ev = CapacityEvent("host_failure", tier=2, fraction=0.3)
    rebalanced, decision = rebalance(cluster, ev)
    assert decision.violations.ok
    # movement bounded: the paper's constraint 3 holds through recovery
    assert (decision.projected.num_moved
            <= int(cluster.problem.move_budget))


def test_fault_injector_deterministic():
    a = FaultInjector(5, seed=42, failure_rate=0.5)
    b = FaultInjector(5, seed=42, failure_rate=0.5)
    ev_a = [a.sample(s) for s in range(20)]
    ev_b = [b.sample(s) for s in range(20)]
    assert [(e.kind, e.tier) for evs in ev_a for e in evs] == \
           [(e.kind, e.tier) for evs in ev_b for e in evs]


def test_injector_schedule_unifies_with_sim_events():
    inj = FaultInjector(5, seed=3, failure_rate=0.3, straggler_rate=0.3)
    timed, advisories = inj.schedule(30)
    assert timed, "seed should produce at least one event in 30 steps"
    # Timed events are sim CapacityScale records with composed scales.
    from repro.sim.events import CapacityScale
    assert all(isinstance(t, CapacityScale) for t in timed)
    assert all(0.0 < t.scale for t in timed)
    # Stacked events on one tier compose multiplicatively against as-built.
    per_tier = {}
    for t in timed:
        per_tier.setdefault(t.tier, []).append(t.scale)
    for scales in per_tier.values():
        assert all(b != a for a, b in zip(scales, scales[1:])) or len(scales) == 1
    # Announced events (stragglers here) ride the PR-4 advisory channel;
    # hard failures stay surprises.
    announced = [t for t in timed if t.announced]
    assert len(advisories) == len(announced)
    for adv, t in zip(advisories, announced):
        assert (adv.at, adv.tier) == (t.at, t.tier)
        assert adv.scale == pytest.approx(t.scale)



# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_schedule(cfg, jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr_schedule(cfg, jnp.asarray(100))) < 2e-4


def test_grad_clip_limits_update():
    cfg = AdamWConfig(lr=1.0, warmup_steps=0, grad_clip=1e-3,
                      weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    _, _, metrics = adamw_update(cfg, {"w": jnp.full(4, 1e6)}, state, params)
    assert float(metrics["grad_norm"]) > 1e5   # raw norm reported


def test_microbatched_step_matches_full():
    cfg = reduce_for_smoke(get_config("smollm-360m"))
    cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                     cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                      cfg.vocab_size),
    }
    s0 = init_train_state(model, jax.random.PRNGKey(0))
    full = make_train_step(model)(s0, batch)
    s0b = init_train_state(model, jax.random.PRNGKey(0))
    micro = make_train_step(model, microbatches=2)(s0b, batch)
    np.testing.assert_allclose(float(full[1]["loss"]),
                               float(micro[1]["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(full[0].params),
                    jax.tree.leaves(micro[0].params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-4)
