"""The curated public API surface (ISSUE 9 satellite).

``repro.__all__`` is the stability contract: every name on it must
resolve, must be the same object as its home-module definition (no stale
re-export shadowing a refactor), and must cover what the examples and the
three documented workflows actually import.  Deep modules stay importable
but are deliberately *not* asserted here — only the curated surface is
pinned.
"""

import ast
import importlib
import pathlib

import pytest

import repro

REPO = pathlib.Path(__file__).resolve().parents[1]

# Where each public name is defined (the module whose attribute must be
# identical to the top-level re-export).
_HOME = {
    "Sptlb": "repro.core.sptlb",
    "BalanceDecision": "repro.core.sptlb",
    "CoopConfig": "repro.core.sptlb",
    "Problem": "repro.core.problem",
    "make_problem": "repro.core.problem",
    "ClusterState": "repro.core.telemetry",
    "generate_cluster": "repro.core.telemetry",
    "utilization_fraction": "repro.core.problem",
    "BalanceController": "repro.core.controller",
    "ControllerConfig": "repro.core.controller",
    "FaultToleranceConfig": "repro.core.controller",
    "Mode": "repro.core.controller",
    "TickInput": "repro.core.controller",
    "TickResult": "repro.core.controller",
    "Advisory": "repro.core.planner",
    "ServiceLoop": "repro.service.loop",
    "ServiceConfig": "repro.service.loop",
    "ServiceStepResult": "repro.service.loop",
    "ServiceEvent": "repro.service.events",
    "TelemetryDelta": "repro.service.events",
    "CapacityUpdate": "repro.service.events",
    "AppArrival": "repro.service.events",
    "AppDeparture": "repro.service.events",
    "AdvisoryBatch": "repro.service.events",
    "FaultSignal": "repro.service.events",
    "LatencyDelta": "repro.service.events",
    "DriftConfig": "repro.service.drift",
    "DriftDetector": "repro.service.drift",
    "FleetShadow": "repro.service.shadow",
    "Scenario": "repro.sim.scenario",
    "get_scenario": "repro.sim.scenario",
    "list_scenarios": "repro.sim.scenario",
    "run_netlat_pair": "repro.sim.harness",
    "run_pair": "repro.sim.harness",
    "run_scenario": "repro.sim.harness",
    "run_scenario_service": "repro.sim.harness",
    "run_service_pair": "repro.sim.harness",
    "netlat_compare": "repro.sim.slo",
    "service_compare": "repro.sim.slo",
    "StreamApp": "repro.streams.router",
    "StreamRouter": "repro.streams.router",
    "PodSlice": "repro.streams.router",
    "build_cluster": "repro.streams.router",
}


def test_all_names_resolve():
    missing = [n for n in repro.__all__ if not hasattr(repro, n)]
    assert missing == []


def test_all_is_sorted_within_no_dupes():
    assert len(set(repro.__all__)) == len(repro.__all__)


def test_home_map_covers_the_surface():
    """Every public name (bar the version string) has a pinned home."""
    assert set(_HOME) == set(repro.__all__) - {"__version__"}


@pytest.mark.parametrize("name", sorted(_HOME))
def test_reexport_is_identical_to_home_definition(name):
    home = importlib.import_module(_HOME[name])
    assert getattr(repro, name) is getattr(home, name), (
        f"repro.{name} is not {_HOME[name]}.{name} — stale re-export?")


def test_version_is_a_pep440ish_string():
    assert isinstance(repro.__version__, str)
    assert all(part.isdigit() for part in repro.__version__.split("."))


def _imported_repro_names(path: pathlib.Path) -> dict[str, set]:
    """{module: {names}} for every ``repro``-rooted import in the file."""
    tree = ast.parse(path.read_text())
    out: dict[str, set] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module and (
                node.module == "repro" or node.module.startswith("repro.")):
            out.setdefault(node.module, set()).update(
                a.name for a in node.names)
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro" or a.name.startswith("repro."):
                    out.setdefault(a.name, set())
    return out


@pytest.mark.parametrize(
    "example", sorted(p.name for p in (REPO / "examples").glob("*.py")))
def test_examples_import_the_curated_surface(example):
    """Examples are the API's showroom: every name they pull from the
    top-level package is on ``__all__``, and any deep import they still
    need is a name the curated surface does not carry (harness extras
    like chaos/overload runners), never a shadow path to a public name."""
    imports = _imported_repro_names(REPO / "examples" / example)
    public = set(repro.__all__)
    for mod, names in imports.items():
        if mod == "repro":
            assert names <= public, (example, names - public)
        else:
            leaked = {n for n in names if n in public}
            assert not leaked, (
                f"{example} imports {sorted(leaked)} from {mod}; those are "
                f"public — import them from repro directly")


def test_deep_modules_stay_importable():
    for mod in ("repro.core", "repro.service", "repro.sim", "repro.shard",
                "repro.streams"):
        importlib.import_module(mod)
