"""Batched top-k LocalSearch, shape-bucketed padding, and the vectorized
cooperation loop: invariants, parity with the single-move/seed semantics,
and the fused best-per-app kernel contract."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CoopConfig, LocalSearchConfig, RegionScheduler,
                        HostScheduler, Sptlb, generate_cluster, objective,
                        pad_problem, solve_local, validate)
from repro.core.constraints import move_mask, moves_remaining
from repro.core.delta import move_delta_cost
from repro.core.problem import bucket_size, tier_loads
from repro.core.solver_local import _weights_vector

from _hypothesis_compat import hypothesis
from test_solver import problems


# ---------------------------------------------------------------------------
# batched top-k move application
# ---------------------------------------------------------------------------

def _single_move_reference(problem, sweeps):
    """The seed's single-move LocalSearch semantics, re-implemented plainly:
    argmin over the masked sweep, commit one move, repeat."""
    x = problem.assignment0
    util, tasks = tier_loads(problem, x)
    wvec = _weights_vector(problem)
    T = problem.num_tiers
    for _ in range(sweeps):
        delta = move_delta_cost(
            problem.demand, problem.tasks, problem.criticality, x,
            problem.assignment0, problem.capacity, problem.task_limit,
            problem.ideal_frac, problem.ideal_task_frac, util, tasks, wvec)
        mask = move_mask(problem, x, util, tasks, moves_remaining(problem, x))
        scores = jnp.where(mask, delta, jnp.inf)
        flat = int(jnp.argmin(scores))
        n, t = flat // T, flat % T
        if not float(scores[n, t]) < -1e-7:
            break
        src = int(x[n])
        x = x.at[n].set(t)
        util = util.at[src].add(-problem.demand[n]).at[t].add(problem.demand[n])
        tasks = tasks.at[src].add(-problem.tasks[n]).at[t].add(problem.tasks[n])
    return x


def test_batch_moves_1_reproduces_single_move_path(cluster300):
    """batch_moves=1 must follow the seed's single-move trajectory exactly."""
    p = cluster300.problem
    res = solve_local(p, LocalSearchConfig(max_iters=12, batch_moves=1))
    x_ref = _single_move_reference(p, 12)
    assert np.array_equal(np.asarray(res.assignment), np.asarray(x_ref))


def test_batched_commits_more_moves_per_sweep(cluster300):
    """The point of the tentpole: >1 committed move per candidate sweep."""
    p = cluster300.problem
    res = solve_local(p, LocalSearchConfig(max_iters=8, batch_moves=16,
                                           batch_quality=0.5))
    assert res.extra["committed_moves"] > res.extra["sweeps"]
    assert validate(p, res.assignment).ok


@hypothesis.given(problems())
@hypothesis.settings(max_examples=12, deadline=None, derandomize=True,
                     suppress_health_check=[hypothesis.HealthCheck.too_slow])
def test_property_batched_feasible_and_no_worse_at_equal_sweeps(p):
    """(a) every hard constraint holds on every random instance; (b) at an
    equal candidate-sweep count the batched path reaches an objective no
    worse than the single-move path (it commits the single-move path's move
    first each sweep, plus only strictly-improving comparable extras).

    (b) is the pre-convergence claim — once the single-move path converges
    within the sweep budget both paths sit in (possibly different) local
    minima and the comparison is between minima, not throughput — so it is
    only asserted while the single-move run is still moving."""
    sweeps = 12
    r1 = solve_local(p, LocalSearchConfig(max_iters=sweeps, batch_moves=1))
    for bm, q in ((8, 0.9), (16, 0.5)):
        rk = solve_local(p, LocalSearchConfig(max_iters=sweeps, batch_moves=bm,
                                              batch_quality=q))
        v = validate(p, rk.assignment)
        assert v.ok, v
        if not r1.converged:
            assert rk.objective <= r1.objective + 1e-4 * max(1.0, abs(r1.objective))


def test_batched_no_worse_at_equal_sweeps_calibrated(cluster300):
    """Strict (b) on the paper-calibrated workload, pre-convergence sweeps."""
    p = cluster300.problem
    for sweeps in (8, 16):
        r1 = solve_local(p, LocalSearchConfig(max_iters=sweeps, batch_moves=1))
        rk = solve_local(p, LocalSearchConfig(max_iters=sweeps, batch_moves=16))
        assert rk.objective <= r1.objective + 1e-4 * max(1.0, abs(r1.objective)), sweeps


@hypothesis.given(problems())
@hypothesis.settings(max_examples=8, deadline=None, derandomize=True,
                     suppress_health_check=[hypothesis.HealthCheck.too_slow])
def test_property_batched_never_worse_than_initial(p):
    res = solve_local(p, LocalSearchConfig(max_iters=64, batch_moves=16))
    assert validate(p, res.assignment).ok
    assert res.objective <= float(objective(p, p.assignment0)) + 1e-5


# ---------------------------------------------------------------------------
# shape-bucketed padding
# ---------------------------------------------------------------------------

def test_bucket_size_powers_of_two():
    assert bucket_size(1) == 256
    assert bucket_size(256) == 256
    assert bucket_size(257) == 512
    assert bucket_size(5000) == 8192


def test_padded_problem_solves_identically(cluster300):
    p = cluster300.problem
    pp = pad_problem(p)
    assert pp.num_apps == 512
    assert int(pp.move_budget) == int(p.move_budget)
    cfg = LocalSearchConfig(max_iters=48, batch_moves=16)
    res = solve_local(p, cfg)
    res_p = solve_local(pp, cfg)
    assert np.array_equal(np.asarray(res_p.assignment[:p.num_apps]),
                          np.asarray(res.assignment))
    # padding rows never move
    assert np.array_equal(np.asarray(res_p.assignment[p.num_apps:]),
                          np.asarray(pp.assignment0[p.num_apps:]))
    assert abs(res_p.objective - res.objective) < 1e-4 * max(1.0, abs(res.objective))


def test_padded_optimal_search_is_finite_and_feasible(cluster300):
    from repro.core import OptimalSearchConfig, solve_optimal
    p = cluster300.problem
    pp = pad_problem(p)
    res = solve_optimal(pp, OptimalSearchConfig(steps=40))
    assert np.isfinite(res.objective)
    assert validate(p, res.assignment[:p.num_apps]).ok


def test_sptlb_bucketing_reuses_compiled_executable():
    """Drifting app counts within one bucket must not retrace LocalSearch."""
    from repro.core.solver_local import local_search_trace_count
    decisions = []
    counts = []
    for i, n in enumerate((290, 300, 310)):
        cluster = generate_cluster(num_apps=n, seed=20 + i)
        before = local_search_trace_count()
        d = Sptlb(cluster).balance("local", timeout_s=4,
                                   config=CoopConfig(variant="no_cnst"))
        counts.append(local_search_trace_count() - before)
        decisions.append(d)
        assert d.solve.extra["bucket"] == 512
        assert d.solve.extra["padded_from"] == n
        assert d.violations.ok
    # at most the first call may trace; the rest must hit the jit cache
    assert sum(counts[1:]) == 0, counts


# ---------------------------------------------------------------------------
# vectorized hierarchy (region matrix + prefix FFD)
# ---------------------------------------------------------------------------

def test_region_matrix_matches_naive_check(cluster300):
    region = RegionScheduler(cluster300)
    c = cluster300
    N, T = c.problem.num_apps, c.problem.num_tiers
    rng = np.random.default_rng(0)
    apps = rng.integers(0, N, 200)
    tiers = rng.integers(0, T, 200)
    fast = region.check_many(apps, tiers)
    for a, t, ok in zip(apps, tiers, fast):
        dst = np.where(c.tier_regions[t])[0]
        worst = c.region_latency[c.app_region[a], dst].max()
        assert (worst <= region.budget) == bool(ok)
    # full matrix agrees with pointwise checks
    mat = region.feasibility_matrix()
    assert mat.shape == (N, T)
    assert mat[apps, tiers].tolist() == fast.tolist()


def test_region_scheduler_rejects_regionless_tier(cluster300):
    """A tier with no regions must reject every placement (the precomputed
    matrix must not let the -inf empty-max read as 'within budget')."""
    c = dataclasses.replace(cluster300,
                            tier_regions=cluster300.tier_regions.copy())
    c.tier_regions[2, :] = False
    region = RegionScheduler(c)
    assert not region.check(0, 2)
    assert not region.feasibility_matrix()[:, 2].any()
    # other tiers unaffected
    assert region.feasibility_matrix()[:, 0].any()


def _ffd_reference(cluster, tier, apps):
    """The seed's O(M*H) first-fit-decreasing, kept as the packing oracle."""
    c = cluster
    demand = np.asarray(c.problem.demand)[apps]
    order = np.argsort(-demand.max(axis=1))
    hosts = np.tile(c.host_capacity, (int(c.hosts_per_tier[tier]), 1))
    rejected = []
    for i in order:
        fit = np.all(hosts >= demand[i], axis=1)
        if not fit.any():
            rejected.append(int(apps[i]))
            continue
        h = int(np.argmax(fit))
        hosts[h] -= demand[i]
    return rejected


@pytest.mark.parametrize("seed,count", [(0, 60), (1, 150), (2, 299)])
def test_host_scheduler_prefix_ffd_matches_reference(cluster300, seed, count):
    host = HostScheduler(cluster300)
    rng = np.random.default_rng(seed)
    apps = rng.choice(cluster300.problem.num_apps, size=count, replace=False)
    for tier in range(cluster300.problem.num_tiers):
        got = sorted(host.check_tier(tier, apps))
        want = sorted(_ffd_reference(cluster300, tier, apps))
        assert got == want, (tier, got, want)


def test_cooperate_reports_phase_timings(cluster300):
    d = Sptlb(cluster300).balance("local", timeout_s=4,
                                  config=CoopConfig(max_rounds=6))
    tm = d.cooperation.timings
    for key in ("solve_s", "region_s", "host_s", "feedback_s",
                "total_s", "host_side_frac"):
        assert key in tm, tm
    assert tm["total_s"] > 0
    assert 0.0 <= tm["host_side_frac"] <= 1.0
    assert d.solve.extra["coop_timings"] == tm
