"""Deterministic fallback for the optional ``hypothesis`` dev dependency.

Tier-1 must collect and run without optional packages.  When hypothesis is
installed we re-export it untouched; otherwise a minimal deterministic
stand-in runs each ``@given`` test over a fixed set of seeded examples
(seeds are constants, so failures reproduce exactly).

Only the API surface this test-suite uses is implemented:
``st.integers`` / ``st.sampled_from`` / ``st.composite``,
``hypothesis.given`` / ``hypothesis.settings`` / ``hypothesis.HealthCheck``.
Unknown ``settings`` kwargs (deadline, derandomize, suppress_health_check,
...) are accepted and ignored.
"""
from __future__ import annotations

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import types

    import numpy as np

    class _Strategy:
        """A strategy is just a sampler: rng -> value."""

        def __init__(self, sample):
            self.sample = sample

    def _integers(lo, hi):
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def _sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[int(rng.integers(len(options)))])

    def _composite(fn):
        def build(*args, **kw):
            def sample(rng):
                return fn(lambda s: s.sample(rng), *args, **kw)
            return _Strategy(sample)
        return build

    st = types.SimpleNamespace(
        integers=_integers, sampled_from=_sampled_from, composite=_composite)

    def _settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def _given(*strategies):
        def deco(fn):
            n = getattr(fn, "_compat_max_examples", 10)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for i in range(n):
                    rng = np.random.default_rng(0xC0FFEE + 7919 * i)
                    vals = [s.sample(rng) for s in strategies]
                    fn(*args, *vals, **kwargs)

            # Hide the strategy-supplied trailing params from pytest's
            # fixture resolution (real hypothesis does the same).
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())[:-len(strategies)]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper
        return deco

    class _HealthCheck:
        too_slow = "too_slow"

    hypothesis = types.SimpleNamespace(
        given=_given, settings=_settings, HealthCheck=_HealthCheck)
