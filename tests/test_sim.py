"""Fleet simulator: workload engine, events, scenario registry, harness,
and the acceptance margins (controller beats no-rebalance on flash_crowd
and tier_drain; churn keeps one executable per pow-2 bucket)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hierarchy import RegionScheduler
from repro.core.telemetry import sample_app_population
from repro.sim import (CapacityScale, RegionOutage, RegionRestore,
                       WorkloadConfig, build_fleet, get_scenario,
                       inject_flash_crowd, list_scenarios, make_workload_state,
                       place_arrivals, run_pair, run_scenario, workload_step)
from repro.sim.events import MIN_TIER_SCALE, OUTAGE_LATENCY_MS


# ---------------------------------------------------------------------------
# workload engine
# ---------------------------------------------------------------------------

def _tiny_state(n=64, seed=0, **kw):
    rng = np.random.default_rng(seed)
    base, tasks, _, _ = sample_app_population(rng, n)
    valid = np.ones(n, bool)
    return make_workload_state(base, tasks, valid, seed=seed, **kw)


def test_workload_step_deterministic_and_positive():
    cfg = WorkloadConfig()
    s1, s2 = _tiny_state(seed=3), _tiny_state(seed=3)
    for _ in range(3):
        s1, d1, t1, v1 = workload_step(cfg, s1)
        s2, d2, t2, v2 = workload_step(cfg, s2)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    assert (np.asarray(d1) > 0).all()
    assert (np.asarray(t1) >= 1).all()          # live apps keep >= 1 task


def test_flash_crowd_spikes_then_decays():
    cfg = WorkloadConfig(burst_sigma=0.0, diurnal_amp=0.0, flash_decay=0.8)
    s = _tiny_state()
    s = inject_flash_crowd(s, np.array([0, 1]), magnitude=8.0)
    s, d, _, _ = workload_step(cfg, s)
    base = np.asarray(s.base_demand)
    assert np.asarray(d)[0, 0] > 4 * base[0, 0]          # spiked
    assert abs(np.asarray(d)[5, 0] - base[5, 0]) < 1e-4  # untouched app
    for _ in range(40):
        s, d, _, _ = workload_step(cfg, s)
    assert np.asarray(d)[0, 0] < 1.1 * base[0, 0]        # decayed back


def test_churn_flips_valid_mask_only():
    cfg = WorkloadConfig()
    s = _tiny_state(retire_rate=0.5, arrival_rate=5.0)
    n = np.asarray(s.valid).size
    seen_live = set()
    for _ in range(10):
        s, d, t, v = workload_step(cfg, s)
        assert np.asarray(d).shape == (n, 2)             # shapes never drift
        seen_live.add(int(np.asarray(v).sum()))
    assert len(seen_live) > 1                            # churn happened


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------

def test_capacity_scale_and_region_outage_rewrite_cluster():
    sc = get_scenario("steady_diurnal", num_apps=96, ticks=8)
    fleet = build_fleet(sc)
    cap0 = np.asarray(fleet.cluster.problem.capacity).copy()

    CapacityScale(at=0, tier=2, scale=0.05).apply(fleet)
    cap = np.asarray(fleet.cluster.problem.capacity)
    np.testing.assert_allclose(cap[2], cap0[2] * 0.05, rtol=1e-5)
    np.testing.assert_allclose(cap[0], cap0[0], rtol=1e-5)
    assert fleet.cluster.hosts_per_tier[2] >= 1

    RegionOutage(at=0, region=0).apply(fleet)
    affected = fleet.cluster.tier_regions[:, 0]
    slo = np.asarray(fleet.cluster.problem.slo_allowed)
    assert not slo[affected].any()                       # eligibility lost
    assert (fleet.cluster.region_latency[0] >= OUTAGE_LATENCY_MS).all()
    cap_out = np.asarray(fleet.cluster.problem.capacity)
    assert (cap_out[affected] <= cap[affected] + 1e-5).all()
    assert (cap_out >= cap0 * MIN_TIER_SCALE - 1e-6).all()   # never zero

    RegionRestore(at=0, region=0).apply(fleet)
    slo2 = np.asarray(fleet.cluster.problem.slo_allowed)
    np.testing.assert_array_equal(slo2, fleet.base_slo_allowed)
    np.testing.assert_allclose(np.asarray(fleet.cluster.problem.capacity)[0],
                               cap0[0], rtol=1e-5)       # tier 0 untouched
    np.testing.assert_allclose(fleet.cluster.region_latency,
                               fleet.base_latency, rtol=1e-6)


def test_region_restore_reenables_premask_eligibility():
    """The planner pre-evacuates against the §3.4 premask, so a restore
    must hand the region scheduler back exactly the pre-outage
    feasibility matrix (the premask is memoized per cluster — a stale
    cache here would keep the region dark forever)."""
    sc = get_scenario("region_outage", num_apps=96, ticks=8)
    fleet = build_fleet(sc)
    feas0 = RegionScheduler(fleet.cluster).feasibility_matrix().copy()
    assert feas0.any()

    RegionOutage(at=0, region=0).apply(fleet)
    feas_out = RegionScheduler(fleet.cluster).feasibility_matrix()
    lost = feas0 & ~feas_out
    assert lost.any()                          # the outage closed placements
    assert not (feas_out & ~feas0).any()       # and never opened new ones

    RegionRestore(at=0, region=0).apply(fleet)
    feas_back = RegionScheduler(fleet.cluster).feasibility_matrix()
    np.testing.assert_array_equal(feas_back, feas0)


def test_overlapping_capacity_and_outage_events_compose():
    """FleetState.refresh is the single composition point: a capacity scale
    and a region outage on the same tier multiply, and unwinding one knob
    leaves the other exactly in place."""
    sc = get_scenario("steady_diurnal", num_apps=96, ticks=8)
    fleet = build_fleet(sc)
    cap0 = np.asarray(fleet.cluster.problem.capacity).copy()
    affected = fleet.cluster.tier_regions[:, 0]
    tier = int(np.where(affected)[0][0])
    regions = fleet.cluster.tier_regions[tier]
    live_share = (regions & ~np.eye(len(regions), dtype=bool)[0]).sum() / regions.sum()

    CapacityScale(at=0, tier=tier, scale=0.5).apply(fleet)
    RegionOutage(at=0, region=0).apply(fleet)
    cap = np.asarray(fleet.cluster.problem.capacity)
    np.testing.assert_allclose(cap[tier], cap0[tier] * 0.5 * live_share,
                               rtol=1e-5)

    # Restoring the region must leave the standing capacity scale intact...
    RegionRestore(at=0, region=0).apply(fleet)
    np.testing.assert_allclose(np.asarray(fleet.cluster.problem.capacity)[tier],
                               cap0[tier] * 0.5, rtol=1e-5)
    # ...and unwinding the scale recovers as-built exactly.
    CapacityScale(at=0, tier=tier, scale=1.0).apply(fleet)
    np.testing.assert_allclose(np.asarray(fleet.cluster.problem.capacity),
                               cap0, rtol=1e-5)


def test_declared_events_channel():
    """Maintenance events publish advisories; surprises never do."""
    drain = get_scenario("tier_drain", num_apps=96, ticks=40)
    advisories = drain.declared_events
    assert len(advisories) == len(drain.events)
    assert all(a.kind == "capacity" and a.tier == 2 for a in advisories)
    assert [a.at for a in advisories] == sorted(a.at for a in advisories)

    outage = get_scenario("region_outage", num_apps=96, ticks=40)
    assert {a.kind for a in outage.declared_events} == {"outage", "restore"}

    flash = get_scenario("flash_crowd", num_apps=96, ticks=40)
    assert flash.declared_events == ()

    # Per-event opt-out: an unannounced drain stays off the channel.
    quiet = dataclasses.replace(
        drain, events=tuple(dataclasses.replace(e, announced=False)
                            for e in drain.events))
    assert quiet.declared_events == ()


def test_place_arrivals_respects_slo_table():
    sc = get_scenario("churn_heavy", num_apps=96, ticks=8)
    fleet = build_fleet(sc)
    problem = fleet.cluster.problem
    standby = np.where(~np.asarray(problem.valid))[0][:7]
    # pretend they just arrived
    valid = np.asarray(problem.valid).copy()
    valid[standby] = True
    fleet.cluster = dataclasses.replace(
        fleet.cluster, problem=dataclasses.replace(
            problem, valid=jnp.asarray(valid)))
    x = place_arrivals(fleet, standby)
    slo = np.asarray(problem.slo)
    allowed = np.asarray(problem.slo_allowed)
    for n in standby:
        assert allowed[x[n], slo[n]], (n, x[n], slo[n])


# ---------------------------------------------------------------------------
# scenario registry end-to-end (acceptance: all five through the controller)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(list_scenarios()))
def test_registry_scenarios_run_through_controller(name):
    sc = get_scenario(name, num_apps=96, ticks=10, seed=1)
    rep = run_scenario(sc, policy="balanced")
    s = rep.summary()
    assert s["ticks"] == 10
    assert all(np.isfinite(t.d2b) for t in rep.ticks)
    assert all(t.live_apps > 0 for t in rep.ticks)
    # the controller actually engaged with the trajectory
    assert s["triggers"] >= 1
    # series + summary agree
    assert sum(rep.series()["moved"]) == s["total_moves"]


# ---------------------------------------------------------------------------
# acceptance margins: balancing beats the static baseline
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def flash_pair():
    return run_pair(get_scenario("flash_crowd", num_apps=160, ticks=40,
                                 seed=0))


@pytest.fixture(scope="module")
def drain_pair():
    return run_pair(get_scenario("tier_drain", num_apps=160, ticks=40,
                                 seed=0))


def test_flash_crowd_controller_beats_baseline(flash_pair):
    cmp = flash_pair["compare"]
    # measured ~0.09 violation-tick ratio; assert with a generous margin
    assert cmp["slo_violation_ticks"]["balanced"] < \
        cmp["slo_violation_ticks"]["baseline"]
    assert cmp["slo_violation_ticks"]["ratio"] < 0.6
    assert cmp["over_ideal_excess_integral"]["ratio"] < 0.6
    assert cmp["mean_d2b"]["ratio"] < 0.9


def test_tier_drain_controller_beats_baseline(drain_pair):
    cmp = drain_pair["compare"]
    # measured ~0.70; the drain staircase caps how fast evacuation can go
    # (movement budget), so the margin is modest by design
    assert cmp["slo_violation_ticks"]["balanced"] < \
        cmp["slo_violation_ticks"]["baseline"]
    assert cmp["slo_violation_ticks"]["ratio"] < 0.9
    assert cmp["over_ideal_excess_integral"]["ratio"] < 0.9


def test_tier_drain_respects_movement_budget(drain_pair):
    """Maintenance evacuation is priced: the trajectory's movement cost
    stays inside the scenario budget and the scorecard says so."""
    cmp = drain_pair["compare"]
    summary = drain_pair["balanced"].summary()
    assert summary["move_budget"] is not None
    assert cmp["movement"]["budget"] == summary["move_budget"]
    assert cmp["movement"]["within_budget"]
    assert 0 < cmp["movement"]["cost"] <= summary["move_budget"]
    assert summary["movement_cost"] == pytest.approx(
        summary["audit"]["movement_cost"], abs=1e-3)


def test_anticipation_never_worse_and_moves_less(drain_pair):
    """The declared drain is known in advance: planning against it must
    not lose on violations and should spend less movement than reacting
    to each capacity step after it bites."""
    assert drain_pair["balanced"].extra["anticipation"]
    blind = run_pair(get_scenario("tier_drain", num_apps=160, ticks=40,
                                  seed=0), anticipation=False)
    assert not blind["balanced"].extra["anticipation"]
    ant_cmp, blind_cmp = drain_pair["compare"], blind["compare"]
    assert (ant_cmp["slo_violation_ticks"]["balanced"]
            <= blind_cmp["slo_violation_ticks"]["balanced"])
    assert (ant_cmp["movement"]["cost"]
            <= blind_cmp["movement"]["cost"] * 1.05)


def test_region_breach_accounting_present(drain_pair):
    """Maintenance placement mode's latency degradation is priced, never
    silent: both policies report region-breach app-ticks."""
    for policy in ("baseline", "balanced"):
        s = drain_pair[policy].summary()
        assert s["region_breach_app_ticks"] >= 0


def test_controller_pays_moves_for_the_win(flash_pair):
    """The win is not free: the balanced run moved apps (downtime proxy)
    and the report accounts for every one of them."""
    balanced = flash_pair["balanced"]
    assert balanced.summary()["total_moves"] > 0
    assert balanced.extra["audit"]["total_moved"] == \
        balanced.summary()["total_moves"]


# ---------------------------------------------------------------------------
# acceptance: churn via valid-mask padding keeps compiled executables
# ---------------------------------------------------------------------------

def test_churn_trajectory_single_retrace_per_bucket():
    sc = get_scenario("churn_heavy", num_apps=128, ticks=24, seed=2)
    rep = run_scenario(sc, policy="balanced")
    live = [t.live_apps for t in rep.ticks]
    assert min(live) != max(live)                  # app count actually drifted
    # one pool -> one pow-2 bucket -> at most one (re)trace for the whole
    # trajectory (0 if an earlier test already compiled this bucket)
    assert rep.extra["solver_retraces"] <= 1
    assert rep.extra["workload_retraces"] <= 1
    assert rep.summary()["rebalances"] >= 2        # the solver actually ran


def test_runs_are_deterministic():
    sc = get_scenario("steady_diurnal", num_apps=96, ticks=8, seed=4)
    a = run_scenario(sc, policy="static")
    b = run_scenario(sc, policy="static")
    assert [t.d2b for t in a.ticks] == [t.d2b for t in b.ticks]


def test_static_and_balanced_share_workload_trajectory():
    """The comparison is only fair if both policies see the same demand
    process: controller actions must not feed back into the workload.
    Live-app counts depend only on the workload state, so the churn series
    must match tick for tick across policies."""
    sc = get_scenario("churn_heavy", num_apps=96, ticks=10, seed=4)
    a = run_scenario(sc, policy="static")
    b = run_scenario(sc, policy="balanced")
    assert [t.live_apps for t in a.ticks] == [t.live_apps for t in b.ticks]
