"""Perf-variant code paths (EXPERIMENTS.md §Perf): numerics must be
preserved by every optimization flag.

Multi-device checks (EP MoE, batch-sharded attention) run in a subprocess
with 8 host devices so the main test process keeps its single-device jax.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model, reduce_for_smoke

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# ring cache (§Perf A4) — single device
# ---------------------------------------------------------------------------

def test_ring_cache_matches_full_cache_across_wrap():
    cfg = dataclasses.replace(reduce_for_smoke(get_config("gemma2-9b")),
                              ring_cache=True)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 24                       # reduced window = 16 < 24 -> wraps
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = jax.jit(model.forward_train)(params, {"tokens": toks})

    cache = model.init_cache(B, 64)
    # local (ring) cache is window-sized; global cache is full-sized
    assert cache["layers"][0]["k"].shape[2] == cfg.window
    assert cache["layers"][1]["k"].shape[2] == 64

    _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :20]}, cache)
    dec = jax.jit(model.decode_step)
    for t in range(20, S):
        logits, cache = dec(params, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]),
                                   atol=5e-3, rtol=5e-3)


def test_ring_cache_prefill_shorter_than_window():
    cfg = dataclasses.replace(reduce_for_smoke(get_config("gemma2-9b")),
                              ring_cache=True)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 10                        # < window (16): no wrap
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = jax.jit(model.forward_train)(params, {"tokens": toks})
    cache = model.init_cache(B, 32)
    _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :S - 1]},
                                      cache)
    logits, _ = jax.jit(model.decode_step)(params, toks[:, S - 1:S], cache)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), atol=5e-3, rtol=5e-3)


# ---------------------------------------------------------------------------
# microbatching via scan-xs (§Perf B2) — grad equivalence
# ---------------------------------------------------------------------------

def test_unrolled_microbatches_match_scanned():
    from repro.train.train_step import init_train_state, make_train_step
    cfg = dataclasses.replace(reduce_for_smoke(get_config("olmo-1b")),
                              remat=False)
    model = build_model(cfg)
    batch = {
        "tokens": jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size),
        "targets": jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size),
    }
    s1 = init_train_state(model, KEY)
    s2 = init_train_state(model, KEY)
    scanned = make_train_step(model, microbatches=2)(s1, batch)
    unrolled = make_train_step(model, microbatches=2, unroll=True)(s2, batch)
    np.testing.assert_allclose(float(scanned[1]["loss"]),
                               float(unrolled[1]["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(scanned[0].params),
                    jax.tree.leaves(unrolled[0].params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# multi-device numerics (subprocess: 8 host devices)
# ---------------------------------------------------------------------------

_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import build_model, reduce_for_smoke
    from repro.models import moe as MOE

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)

    # --- EP MoE == global MoE ---
    cfg = dataclasses.replace(reduce_for_smoke(get_config("granite-moe-1b-a400m")),
                              param_dtype="float32")
    params = MOE.moe_init(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    with mesh:
        y_g, _ = jax.jit(lambda p, x: MOE._moe_apply_global(cfg, p, x))(params, x)
        cfg_ep = dataclasses.replace(cfg, moe_impl="ep")
        y_e, _ = jax.jit(lambda p, x: MOE.moe_apply(cfg_ep, p, x))(params, x)
    assert float(jnp.max(jnp.abs(y_g - y_e))) < 1e-4, "EP mismatch"

    # --- batch-sharded attention == baseline ---
    cfg = dataclasses.replace(reduce_for_smoke(get_config("smollm-360m")),
                              param_dtype="float32")
    model = build_model(cfg)
    p = model.init(key)
    batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size)}
    with mesh:
        base, _ = jax.jit(model.forward_train)(p, batch)
        cfg_b = dataclasses.replace(cfg, attn_batch_shard=True,
                                    activation_sharding=True)
        model_b = build_model(cfg_b)
        opt, _ = jax.jit(model_b.forward_train)(p, batch)
    assert float(jnp.max(jnp.abs(base - opt))) < 1e-4, "abshard mismatch"
    print("SUBPROCESS_OK")
""")


def test_multidevice_variant_numerics():
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG],
        capture_output=True, text=True, timeout=420,
        # JAX_PLATFORMS must survive the env replacement: without it jax
        # probes for accelerator plugins in the child and can hang forever.
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        cwd=str(__import__("pathlib").Path(__file__).parent.parent))
    assert "SUBPROCESS_OK" in res.stdout, res.stdout + res.stderr


# ---------------------------------------------------------------------------
# sequence-sharded KV + ZeRO-1 sharding specs build for the affected trees
# ---------------------------------------------------------------------------

def test_kvseq_and_zero1_specs():
    from repro.distributed import sharding as SH
    from repro.launch.mesh import make_host_mesh
    from repro.train.optimizer import adamw_init

    mesh = make_host_mesh(data=1, model=1)
    cfg = get_config("gemma2-9b")
    model = build_model(cfg)
    cache_abs = jax.eval_shape(lambda: model.init_cache(8, 1024))
    sh_heads = SH.cache_shardings(mesh, cache_abs, kv_shard="heads")
    sh_seq = SH.cache_shardings(mesh, cache_abs, kv_shard="seq")
    assert len(jax.tree.leaves(sh_heads)) == len(jax.tree.leaves(sh_seq))

    cfg_s = reduce_for_smoke(cfg)
    model_s = build_model(cfg_s)
    params = jax.eval_shape(model_s.init, KEY)
    opt = jax.eval_shape(lambda: adamw_init(params))
    sh = SH.opt_state_shardings(mesh, opt.m, zero1=True)
    assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(opt.m))
