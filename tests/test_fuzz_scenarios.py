"""Invariant fuzzing over random trajectories (ISSUE 7 satellite).

Six fuzz surfaces, >= 200 random trajectories total, each asserting the
control plane's hard invariants — the properties the regression gate pins
on two curated scenarios, checked here across a randomized family:

  * full overload sim trajectories (random surge/flash/churn mixes through
    ``run_scenario(utility=True)``): the movement budget is never overrun
    (shed churn included), admission never admits an app that does not fit
    its priced tier, and the live population never escapes the pool;
  * admission-gate decision trajectories (random arrival streams priced
    against randomly loaded fleets): every ADMIT fits the named tier at
    the admitted cap under hard capacity, degraded caps respect the
    config floor, and DEFER backoff is monotone per app;
  * cooperation passes over randomly perturbed clusters with the premask
    on: zero region rejections and zero resident-set overflows, whatever
    the demand skew;
  * sharded fleet passes (PR 8): partition -> merge stays a bijection,
    the merged mapping strands nobody and never worsens the incumbent,
    whatever the shard count or demand skew;
  * measured-latency trajectories (PR 10): whatever random link weather a
    network scenario throws (degrades, detours, jitter storms), the
    measured netlat+host stack never commits a move whose destination
    tier has a pair over its live p99 budget.

``FUZZ_TRAJECTORIES`` scales every surface proportionally: unset (CI) it
keeps the per-surface defaults below (256 total); a nightly-style run sets
e.g. ``FUZZ_TRAJECTORIES=2000`` for ~9x the coverage.  Values at or below
the default total are ignored — the knob only ever adds examples.

Runs under the ``_hypothesis_compat`` fallback (deterministic seeded
examples) when hypothesis is not installed — tier-1 needs no optional
packages.
"""

import dataclasses
import os

import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import hypothesis, st
from repro.core import CoopConfig, Sptlb, generate_cluster
from repro.core.constraints import FEAS_TOL
from repro.core.goals import objective
from repro.core.problem import tier_loads
from repro.shard import (
    merge_assignment,
    partition_problem,
    plan_shards,
    solve_shards,
    stranded_apps,
)
from repro.shard.solve import ShardSolveConfig
from repro.sim import Scenario, WorkloadConfig, run_scenario
from repro.sim.events import CapacityScale, ChurnRate, FlashCrowd, JitterStorm, LinkDegrade
from repro.streams.admission import AdmissionController, AdmissionState

# Per-surface example counts at the CI default, before the env knob.
_BASE_SIM, _BASE_ADMISSION, _BASE_PREMASK, _BASE_SHARD = 48, 120, 40, 24
_BASE_SERVICE, _BASE_NETLAT = 24, 8
_BASE_TOTAL = (
    _BASE_SIM + _BASE_ADMISSION + _BASE_PREMASK + _BASE_SHARD + _BASE_SERVICE + _BASE_NETLAT
)
_SCALE = max(1.0, int(os.environ.get("FUZZ_TRAJECTORIES", "0")) / _BASE_TOTAL)

# ---------------------------------------------------------------------------
# 1. full overload trajectories (48 examples x 5 ticks, one shape bucket)
# ---------------------------------------------------------------------------

N_SIM_TRAJECTORIES = int(round(_BASE_SIM * _SCALE))


def _random_overload_scenario(seed: int) -> Scenario:
    """A small random overload scenario: every draw keeps the same pool
    size (one jit bucket for all examples) but randomizes the pressure —
    surge rates, flash magnitude/targets, capacity loss, and the budget."""
    rng = np.random.default_rng(seed)
    events = []
    if rng.random() < 0.7:
        events.append(
            ChurnRate(
                at=int(rng.integers(0, 2)),
                arrival_rate=float(rng.uniform(1.0, 4.0)),
                retire_rate=float(rng.uniform(0.0, 0.01)),
            )
        )
    if rng.random() < 0.7:
        events.append(
            FlashCrowd(
                at=int(rng.integers(1, 4)),
                frac=float(rng.uniform(0.2, 0.5)),
                magnitude=float(rng.uniform(2.0, 8.0)),
                crit_below=float(rng.uniform(0.3, 1.0)) if rng.random() < 0.5 else None,
            )
        )
    if rng.random() < 0.4:
        events.append(
            CapacityScale(
                at=int(rng.integers(1, 4)),
                tier=int(rng.integers(0, 5)),
                scale=float(rng.uniform(0.4, 0.8)),
                announced=False,
            )
        )
    return Scenario(
        name=f"fuzz_overload_{seed}",
        description="",
        ticks=5,
        num_apps=20,
        seed=seed,
        overload=True,
        pool_frac=1.6,
        util_scale=float(rng.uniform(0.8, 1.1)),
        arrival_rate=float(rng.uniform(0.5, 2.5)),
        retire_rate=float(rng.uniform(0.0, 0.02)),
        workload=WorkloadConfig(
            period=8,
            diurnal_amp=float(rng.uniform(0.0, 0.3)),
            burst_sigma=float(rng.uniform(0.0, 0.2)),
        ),
        events=tuple(events),
        move_budget=float(rng.uniform(10.0, 60.0)),
    )


@hypothesis.settings(max_examples=N_SIM_TRAJECTORIES, deadline=None)
@hypothesis.given(st.integers(0, 10_000))
def test_fuzz_overload_trajectories_hold_invariants(seed):
    sc = _random_overload_scenario(seed)
    report = run_scenario(sc, utility=True)
    summary = report.summary()
    audit = summary["audit"]
    # Movement budget is a hard ceiling: applied moves + shed churn,
    # lifetime, never exceed it (budget_limited ticks are fine — the
    # budget binding is the design working, overrunning it is the bug).
    assert audit["movement_cost"] <= sc.move_budget + 1e-6, (seed, audit)
    assert summary["budget_overruns"] == 0, (seed, summary)
    # Admission never admitted an app that did not fit its priced tier.
    assert summary["infeasible_admissions"] == 0, (seed, summary)
    # The live population stays inside the pool (shapes are static; an
    # escape means the admission overlay corrupted the valid mask).
    assert all(t.live_apps <= sc.max_apps for t in report.ticks), seed
    # Deferred accounting never goes negative / beyond the pool.
    assert 0 <= summary.get("deferred_backlog", 0) <= sc.max_apps, seed


# ---------------------------------------------------------------------------
# 2. admission-gate decision trajectories (120 examples, pure numpy, fast)
# ---------------------------------------------------------------------------

N_ADMISSION_TRAJECTORIES = int(round(_BASE_ADMISSION * _SCALE))
_BASE_CLUSTER = None


def _base_problem():
    global _BASE_CLUSTER
    if _BASE_CLUSTER is None:
        _BASE_CLUSTER = generate_cluster(num_apps=64, seed=3)
    return _BASE_CLUSTER.problem


@hypothesis.settings(max_examples=N_ADMISSION_TRAJECTORIES, deadline=None)
@hypothesis.given(st.integers(0, 10_000))
def test_fuzz_admission_never_admits_infeasible(seed):
    rng = np.random.default_rng(seed ^ 0xAD317)
    base = _base_problem()
    # Random fleet pressure: scale demand so some trajectories start with
    # headroom and some start saturated.
    scale = float(rng.uniform(0.6, 1.6))
    problem = dataclasses.replace(base, demand=base.demand * jnp.float32(scale))
    gate = AdmissionController()
    mode = str(rng.choice(["normal", "conservative", "safe"]))
    last_retry: dict[str, int] = {}
    for step in range(rng.integers(4, 10)):
        demand = rng.uniform(0.0, 0.08, size=problem.num_resources)
        key = f"fuzz{seed}_{step % 3}"  # repeats exercise the backoff
        d = gate.decide(
            problem,
            demand=demand,
            tasks=float(rng.integers(1, 12)),
            slo=int(rng.integers(0, 3)),
            criticality=float(rng.uniform(0.0, 1.0)),
            key=key,
            mode=mode,
            now=step,
        )
        if d.admitted:
            util, tier_tasks = tier_loads(problem, problem.assignment0)
            util = np.asarray(util, np.float64)
            cap = np.asarray(problem.capacity, np.float64)
            klim = np.asarray(problem.task_limit, np.float64)
            # The priced tier holds the app at the admitted cap under hard
            # capacity — the invariant the sim recount also pins.
            assert d.tier >= 0, d
            assert 0.0 < d.cap <= 1.0, d
            fits = util[d.tier] + d.cap * demand <= cap[d.tier] + FEAS_TOL
            # Marginal contract: fit is required on every resource the
            # app consumes (a pre-existing overflow on a resource it
            # demands none of is not this admission's doing).
            assert fits[demand > 0.0].all(), (seed, step, d)
            if d.state is AdmissionState.ADMIT_DEGRADED:
                assert mode == "normal", d
                assert d.cap >= gate.config.min_degraded_cap - FEAS_TOL, d
                assert d.declared_utility > 0.0, d
            last_retry.pop(key, None)
        elif d.state is AdmissionState.DEFER:
            assert 1 <= d.retry_after <= gate.config.backoff_cap, d
            # Exponential backoff is monotone per app key until admission
            # or the cap.
            prev = last_retry.get(key, 0)
            assert d.retry_after >= prev or d.retry_after == gate.config.backoff_cap, d
            last_retry[key] = d.retry_after
        else:
            assert d.state is AdmissionState.REJECT
            assert mode == "safe", d
            assert d.reason.startswith("safe-mode"), d
    audit = gate.audit()
    assert audit["decisions"] == len(gate.log)
    total = audit["admit"] + audit["admit_degraded"] + audit["defer"] + audit["reject"]
    assert total == audit["decisions"]


# ---------------------------------------------------------------------------
# 3. premask cooperation passes (40 examples, shared cluster/bucket)
# ---------------------------------------------------------------------------

N_PREMASK_TRAJECTORIES = int(round(_BASE_PREMASK * _SCALE))
_PREMASK_CLUSTER = None


def _premask_cluster():
    global _PREMASK_CLUSTER
    if _PREMASK_CLUSTER is None:
        _PREMASK_CLUSTER = generate_cluster(num_apps=96, seed=11)
    return _PREMASK_CLUSTER


def _unpackable_residents(cluster) -> int:
    """Residents whose tier's *initial* membership fails host FFD packing.

    The no-overflow contract is conditioned on a packable start: a seed
    state whose residents already fail host packing is pre-existing
    overload the machinery tolerates (their placement is the fallback),
    not a returner gap — overflow beyond this count is the bug."""
    from repro.core.hierarchy import HostScheduler

    host = HostScheduler(cluster)
    x0 = np.asarray(cluster.problem.assignment0)
    return sum(
        len(host.check_tier(t, np.where(x0 == t)[0])) for t in range(cluster.problem.num_tiers)
    )


@hypothesis.settings(max_examples=N_PREMASK_TRAJECTORIES, deadline=None)
@hypothesis.given(st.integers(0, 10_000))
def test_fuzz_premask_no_rejections_no_resident_overflow(seed):
    rng = np.random.default_rng(seed ^ 0x93A5)
    cluster = _premask_cluster()
    # Random per-app demand skew (same shapes, same bucket, new pressure).
    skew = rng.uniform(0.5, 1.8, size=(cluster.problem.num_apps, 1))
    problem = dataclasses.replace(
        cluster.problem, demand=cluster.problem.demand * jnp.asarray(skew, jnp.float32)
    )
    skewed = dataclasses.replace(cluster, problem=problem)
    pre_existing = _unpackable_residents(skewed)
    decision = Sptlb(skewed).balance("local", timeout_s=4, config=CoopConfig(premask=True))
    tm = decision.cooperation.timings
    # The premask contract, fuzzed: no region-infeasible proposal ever
    # reaches the region level, whatever the skew.
    assert tm["region_rejections"] == 0, (seed, dict(tm))
    # The host packer never strands more residents than the skew made
    # unpackable before cooperation even ran; on a packable start
    # (pre_existing == 0, most draws) this is the strict zero contract.
    assert tm["resident_overflows"] <= pre_existing, (seed, dict(tm))
    assert decision.violations.ok, seed


# ---------------------------------------------------------------------------
# 4. sharded fleet passes (24 examples, shared cluster, <= 5 shape buckets)
# ---------------------------------------------------------------------------

N_SHARD_TRAJECTORIES = int(round(_BASE_SHARD * _SCALE))
_SHARD_CLUSTER = None


def _shard_cluster():
    global _SHARD_CLUSTER
    if _SHARD_CLUSTER is None:
        _SHARD_CLUSTER = generate_cluster(num_apps=96, seed=7)
    return _SHARD_CLUSTER


@hypothesis.settings(max_examples=N_SHARD_TRAJECTORIES, deadline=None)
@hypothesis.given(st.integers(0, 10_000))
def test_fuzz_sharded_passes_hold_invariants(seed):
    rng = np.random.default_rng(seed ^ 0x54A2D)
    cluster = _shard_cluster()
    # Random per-app demand skew; shapes stay fixed so at most one compile
    # per shard count (S in 1..5 -> <= 5 (S, Nb, Tb) buckets).
    skew = rng.uniform(0.5, 1.8, size=(cluster.problem.num_apps, 1))
    problem = dataclasses.replace(
        cluster.problem, demand=cluster.problem.demand * jnp.asarray(skew, jnp.float32)
    )
    skewed = dataclasses.replace(cluster, problem=problem)
    num_shards = int(rng.integers(1, 6))

    plan = plan_shards(skewed, num_shards)
    sharded = partition_problem(problem, plan)
    # Bijection: every app in exactly one slot; merged incumbents are the
    # global incumbents bit-for-bit.
    ids = sharded.app_ids[sharded.app_ids >= 0]
    assert np.array_equal(np.sort(ids), np.arange(problem.num_apps)), seed
    identity = merge_assignment(problem, sharded, np.asarray(sharded.problems.assignment0))
    assert np.array_equal(identity, np.asarray(problem.assignment0)), seed

    res = solve_shards(sharded, ShardSolveConfig(max_iters=32))
    merged = merge_assignment(problem, sharded, res.x)
    # Hard invariants: nobody stranded, the incumbent never worsened, and
    # no app left its home shard (cross-shard is coordinator-only).
    assert stranded_apps(problem, merged) == 0, (seed, num_shards)
    obj0 = float(objective(problem, problem.assignment0))
    assert float(objective(problem, jnp.asarray(merged))) <= obj0 + 1e-4, seed
    assert (plan.tier_shard[merged] == plan.app_shard).all(), (seed, num_shards)


# ---------------------------------------------------------------------------
# 5. service event streams (PR 9): ingestion integrity under random bursts
# ---------------------------------------------------------------------------

N_SERVICE_TRAJECTORIES = int(round(_BASE_SERVICE * _SCALE))
_SERVICE_CLUSTER = None


def _service_cluster():
    global _SERVICE_CLUSTER
    if _SERVICE_CLUSTER is None:
        _SERVICE_CLUSTER = generate_cluster(num_apps=48, seed=11)
    return _SERVICE_CLUSTER


@hypothesis.settings(max_examples=N_SERVICE_TRAJECTORIES, deadline=None)
@hypothesis.given(st.integers(0, 10_000))
def test_fuzz_service_event_streams_hold_integrity(seed):
    """Random event bursts through the ServiceLoop: whatever mix of
    telemetry, churn, capacity, advisory, and fault events arrives between
    ticks, no event is dropped and every app's applied-sequence log is
    exactly the submission order of the events that touched it."""
    from repro.core.planner import CAPACITY, Advisory
    from repro.service import (AdvisoryBatch, AppArrival, AppDeparture,
                               CapacityUpdate, FaultSignal, ServiceLoop,
                               TelemetryDelta)

    rng = np.random.default_rng(seed ^ 0x5E21CE)
    cluster = _service_cluster()
    loop = ServiceLoop(cluster)
    demand = np.asarray(cluster.problem.demand, np.float64)
    tasks = np.asarray(cluster.problem.tasks, np.float64)
    slo = np.asarray(cluster.problem.slo)
    num_apps = demand.shape[0]
    num_tiers = np.asarray(cluster.problem.capacity).shape[0]
    live = set(range(num_apps))
    expected: dict[int, list[int]] = {}

    def submit(event, touched):
        seq = loop.submit(event)
        for n in touched:
            expected.setdefault(int(n), []).append(seq)

    for tick in range(4):
        for _ in range(int(rng.integers(0, 4))):
            roll = rng.random()
            if roll < 0.5 and live:
                ids = rng.choice(sorted(live), size=min(len(live), int(rng.integers(1, 8))), replace=False)
                scale = rng.uniform(0.6, 1.6, size=(ids.size, 1))
                submit(
                    TelemetryDelta(
                        app_ids=tuple(int(n) for n in ids),
                        demand=demand[ids] * scale,
                        tasks=tasks[ids] * rng.uniform(0.8, 1.2),
                        collected_at=tick,
                    ),
                    ids,
                )
            elif roll < 0.65 and len(live) > 4:
                gone = int(rng.choice(sorted(live)))
                live.discard(gone)
                submit(AppDeparture(app_id=gone), [gone])
            elif roll < 0.8 and len(live) < num_apps:
                back = int(rng.choice(sorted(set(range(num_apps)) - live)))
                live.add(back)
                submit(
                    AppArrival(
                        app_id=back, demand=demand[back] * rng.uniform(0.5, 1.5),
                        tasks=float(tasks[back]), slo=int(slo[back]),
                        tier=int(rng.integers(0, num_tiers)),
                    ),
                    [back],
                )
            elif roll < 0.9:
                submit(
                    AdvisoryBatch(advisories=(
                        Advisory(at=tick + int(rng.integers(2, 9)),
                                 kind=CAPACITY,
                                 scale=float(rng.uniform(0.7, 1.0))),)),
                    [],
                )
            else:
                submit(FaultSignal(source="fuzz", until=tick + 1), [])
        loop.step(tick)

    assert loop.dropped_events == 0, seed
    assert loop.applied_events == loop.submitted, seed
    # Per-app integrity: the log is the submission order, verbatim — no
    # drop, no duplicate, no reorder; strictly increasing by construction.
    assert loop.shadow.applied_seq == expected, seed
    for seqs in loop.shadow.applied_seq.values():
        assert all(a < b for a, b in zip(seqs, seqs[1:])), seed


# ---------------------------------------------------------------------------
# 6. measured-latency trajectories (PR 10): random link weather, one bucket
# ---------------------------------------------------------------------------

N_NETLAT_TRAJECTORIES = int(round(_BASE_NETLAT * _SCALE))


def _random_network_scenario(seed: int) -> Scenario:
    """A small random network_degraded scenario: the pool shape stays
    fixed (one jit bucket) while the link weather — which pairs degrade,
    how hard, whether a detour is one-directional, whether a jitter storm
    fattens every tail — is drawn fresh per example.  Degrade factors stay
    under the sketch bank's plausibility jump limit, as real detours do."""
    rng = np.random.default_rng(seed ^ 0x9E7147)
    t0 = int(rng.integers(1, 3))
    events = []
    for _ in range(int(rng.integers(1, 4))):
        src, dst = (int(r) for r in rng.choice(5, size=2, replace=False))
        events.append(
            LinkDegrade(
                at=t0,
                src=src,
                dst=dst,
                factor=float(rng.uniform(1.4, 2.4)),
                symmetric=bool(rng.random() < 0.7),
            )
        )
    if rng.random() < 0.5:
        events.append(
            JitterStorm(at=t0 + 1, ticks=3, sigma=float(rng.uniform(0.2, 0.5)), seed=seed)
        )
    return Scenario(
        name=f"fuzz_network_{seed}",
        description="",
        ticks=6,
        num_apps=24,
        seed=seed,
        netlat=True,
        workload=WorkloadConfig(period=8, diurnal_amp=0.2, burst_sigma=0.1),
        events=tuple(events),
    )


@hypothesis.settings(max_examples=N_NETLAT_TRAJECTORIES, deadline=None)
@hypothesis.given(st.integers(0, 10_000))
def test_fuzz_measured_stack_never_exceeds_live_budget(seed):
    from repro.sim.harness import SIM_CONTROLLER

    sc = _random_network_scenario(seed)
    cfg = dataclasses.replace(
        SIM_CONTROLLER,
        coop=dataclasses.replace(SIM_CONTROLLER.coop, levels=("netlat", "host")),
    )
    report = run_scenario(sc, config=cfg, netlat=True)
    summary = report.summary()
    # The measured-latency hard invariant: zero committed moves whose
    # destination tier holds a pair over its live p99 budget, whatever
    # the weather.  (The static stack leaks these by design — that contrast
    # is the regression gate's job; this surface pins the measured stack.)
    assert summary["budget_exceeding_moves"] == 0, (seed, summary)
    # The plane calibrated (budgets were real, not the inert fallback) and
    # the run kept its feasibility contract.
    assert report.extra["netlat"]["calibrated"], seed


def test_fuzz_counts_cover_the_contract():
    """The satellite's floor: at least 200 random trajectories total (and
    the env knob only ever scales the coverage up)."""
    total = (
        N_SIM_TRAJECTORIES
        + N_ADMISSION_TRAJECTORIES
        + N_PREMASK_TRAJECTORIES
        + N_SHARD_TRAJECTORIES
        + N_SERVICE_TRAJECTORIES
        + N_NETLAT_TRAJECTORIES
    )
    assert total >= 200
    assert total >= _BASE_TOTAL
