"""Degraded-mode control plane: property tests + chaos scenarios end-to-end.

Property tests pin the two safety contracts the runbook leans on
(docs/degraded_modes.md): the mode machine is hysteretic (degrades
immediately, recovers slowly, never sits healthier than the score
warrants), and SAFE mode never commits a move outside the evacuation set.
The end-to-end tests run the chaos scenario family through
``run_chaos_pair`` and drive a faulty scheduler level through the full
breaker lifecycle (trip -> cooldown -> failed probe -> backoff -> clean
probe -> closed).
"""
import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st
from repro.core import (BalanceController, ControllerConfig, CoopConfig,
                        FaultToleranceConfig, Mode, generate_cluster)
from repro.core.controller import _MODE_RANK, TickInput
from repro.core.health import CLOSED, OPEN
from repro.sim import (faulty_hierarchy, get_scenario, run_chaos_pair)

CHAOS_SCENARIOS = ("telemetry_blackout", "solver_brownout",
                   "cascading_outage")

_CLUSTERS = {}


def cluster_for(seed, num_apps=48):
    key = (seed, num_apps)
    if key not in _CLUSTERS:
        _CLUSTERS[key] = generate_cluster(num_apps=num_apps, seed=seed)
    return _CLUSTERS[key]


# ---------------------------------------------------------------------------
# property: hysteretic mode machine
# ---------------------------------------------------------------------------

@st.composite
def score_sequences(draw):
    n = draw(st.integers(4, 24))
    return [draw(st.integers(0, 100)) / 100.0 for _ in range(n)]


def target_mode(f, score):
    if score < f.safe_below:
        return Mode.SAFE
    if score < f.conservative_below:
        return Mode.CONSERVATIVE
    return Mode.NORMAL


@hypothesis.given(score_sequences())
@hypothesis.settings(max_examples=40, deadline=None, derandomize=True)
def test_mode_machine_is_hysteretic(scores):
    f = FaultToleranceConfig()
    ctl = BalanceController(cluster_for(0),
                            ControllerConfig(fault=FaultToleranceConfig()))
    window = []                       # trailing scores since last transition
    for s in scores:
        before = ctl.mode
        n_transitions = len(ctl.mode_transitions)
        ctl._update_mode(s)
        window.append(s)
        target = target_mode(f, s)
        # Never healthier than the instantaneous score warrants.
        assert _MODE_RANK[ctl.mode] >= _MODE_RANK[target]
        if _MODE_RANK[target] > _MODE_RANK[before]:
            # Degradation is immediate and exact (straight to SAFE if
            # warranted — no stepping down through CONSERVATIVE).
            assert ctl.mode is target
        if _MODE_RANK[ctl.mode] < _MODE_RANK[before]:
            # Recovery is one step at a time...
            assert _MODE_RANK[before] - _MODE_RANK[ctl.mode] == 1
            # ...and only after recover_ticks consecutive clearing scores.
            floor = (f.safe_below if before is Mode.SAFE
                     else f.conservative_below)
            assert len(window) >= f.recover_ticks
            assert all(w >= floor + f.recover_margin
                       for w in window[-f.recover_ticks:])
        if ctl.mode is not before:
            window = []
            # Every transition is audited with the triggering score.
            assert len(ctl.mode_transitions) == n_transitions + 1
            t = ctl.mode_transitions[-1]
            assert (t["from"], t["to"]) == (before.value, ctl.mode.value)
            assert t["score"] == pytest.approx(s, abs=1e-3)
    # Replaying the audit trail from NORMAL reproduces the final mode.
    mode = Mode.NORMAL.value
    for t in ctl.mode_transitions:
        assert t["from"] == mode
        mode = t["to"]
    assert mode == ctl.mode.value


# ---------------------------------------------------------------------------
# property: SAFE commits nothing but evacuations
# ---------------------------------------------------------------------------

@hypothesis.given(st.integers(0, 3), st.integers(2, 30), st.integers(1, 6))
@hypothesis.settings(max_examples=8, deadline=None, derandomize=True)
def test_safe_mode_only_commits_evacuations(seed, spike, n_spiked):
    cluster = cluster_for(seed)
    p = cluster.problem
    demand = np.asarray(p.demand).copy()
    rng = np.random.default_rng(seed * 1000 + spike)
    live = np.where(np.asarray(p.valid))[0]
    hot = rng.choice(live, size=min(n_spiked, live.size), replace=False)
    demand[hot] *= spike              # true world drifted under the blackout
    cluster = dataclasses.replace(cluster, problem=dataclasses.replace(
        p, demand=np.asarray(demand, np.float32)))

    ctl = BalanceController(cluster, ControllerConfig(
        trigger_d2b=-1.0, cooldown_rounds=0,   # always want to rebalance
        fault=FaultToleranceConfig()))
    x_before = np.asarray(cluster.problem.assignment0).copy()
    # Telemetry 6 ticks old: score 0 -> SAFE on this very tick.
    ev = ctl.step(TickInput(now=6, collected_at=0)).event
    assert ev.mode == Mode.SAFE.value

    p_after = ctl.cluster.problem     # sanitized view + committed mapping
    x_after = np.asarray(p_after.assignment0)
    valid = np.asarray(p_after.valid, bool)
    moved = np.where((x_after != x_before) & valid)[0]
    # Reconstruct the evacuation set the controller planned against.
    import jax.numpy as jnp
    evac = ctl._evacuation_mask(p_after.with_assignment0(
        jnp.asarray(x_before)))
    if ev.applied:
        # An applied SAFE decision may still move nothing (the solver kept
        # everyone home) — the contract is containment, not motion.
        assert "evacuation" in ev.reason
        assert evac[moved].all(), "SAFE moved a non-evacuation app"
    else:
        assert moved.size == 0
        if not evac.any():
            assert "hold" in ev.reason


# ---------------------------------------------------------------------------
# end-to-end: chaos scenario family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", CHAOS_SCENARIOS)
def test_chaos_scenario_degrades_safely_and_recovers(name):
    sc = get_scenario(name, num_apps=96, ticks=20, seed=0)
    out = run_chaos_pair(sc)
    c = out["chaos"]
    # The acceptance bar: degraded modes engaged, audited, zero unsafe
    # moves, no budget overruns, and recovery to NORMAL after the fault.
    assert c["degraded_ticks"] > 0, "chaos never degraded the controller"
    assert set(c["modes_entered"]) & {"conservative", "safe"}
    assert c["mode_transitions"], "transitions must be audited"
    assert c["unsafe_moves"] == 0
    assert c["budget_overruns"] == 0
    assert c["recovered"], f"controller stuck degraded: {c['mode_ticks']}"
    ratio = c["degraded_vs_oracle"]["ratio"]
    assert np.isfinite(ratio) and ratio >= 0.0
    # The oracle twin ran the identical workload: same tick count.
    assert out["degraded"].summary()["ticks"] == \
           out["oracle"].summary()["ticks"] == 20


def test_blackout_scenario_reaches_safe_mode():
    sc = get_scenario("telemetry_blackout", num_apps=96, ticks=20, seed=0)
    out = run_chaos_pair(sc)
    assert "safe" in out["chaos"]["modes_entered"]
    # Fault-free twin never leaves NORMAL.
    oracle_modes = set(out["oracle"].series()["mode"])
    assert oracle_modes == {"normal"}


# ---------------------------------------------------------------------------
# end-to-end: faulty level -> breaker lifecycle
# ---------------------------------------------------------------------------

def breaker_controller(cluster):
    return BalanceController(cluster, ControllerConfig(
        trigger_d2b=-1.0, cooldown_rounds=0,
        coop=CoopConfig(levels=("region", "host")),
        fault=FaultToleranceConfig()))


def test_level_fault_trips_breaker_then_recovers():
    cluster = generate_cluster(num_apps=64, seed=2)
    ctl = breaker_controller(cluster)
    faulty = faulty_hierarchy(("region", "host"), "host", "raise")

    ctl.hierarchy_override = faulty
    for t in range(3):                # fail_threshold consecutive failures
        ctl.step(TickInput(now=t, collected_at=t))
    host = ctl.board.breaker("host")
    assert host.state == OPEN
    assert host.trips == 1

    ctl.step(TickInput(now=3, collected_at=3))   # cooldown pass 1 of 2 (bypassed)
    assert host.state == OPEN
    ctl.step(TickInput(now=4, collected_at=4))   # HALF_OPEN probe against still-faulty
    assert host.state == OPEN         # probe failed: re-open...
    assert host.trips == 2
    assert host.cooldown == 4         # ...with the cooldown doubled

    ctl.hierarchy_override = None     # fault clears
    for t in range(5, 9):             # burn cooldown, then the clean probe
        ctl.step(TickInput(now=t, collected_at=t))
    assert host.state == CLOSED
    assert host.probes == 2
    # Region never faulted: its breaker never tripped.
    assert ctl.board.breaker("region").trips == 0
    # The audit carries the trip count.
    assert ctl.audit()["breaker_trips"] == 2


def test_reject_all_level_trips_breaker():
    cluster = generate_cluster(num_apps=64, seed=3)
    ctl = breaker_controller(cluster)
    ctl.hierarchy_override = faulty_hierarchy(
        ("region", "host"), "host", "reject_all")
    for t in range(6):
        ctl.step(TickInput(now=t, collected_at=t))
        if ctl.board.breaker("host").trips:
            break
    assert ctl.board.breaker("host").trips >= 1
