"""Per-arch smoke tests (deliverable f): every assigned architecture
instantiates at a reduced config of the same family and runs one forward +
train step on CPU, asserting output shapes and no NaNs.  Decode paths are
checked for exact consistency with the teacher-forced forward pass.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, cells, input_specs, shape_applicable
from repro.models import build_model, reduce_for_smoke

KEY = jax.random.PRNGKey(0)


def make_smoke_batch(cfg, B=2, S=32):
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(KEY, (B, S, cfg.d_model)),
            "mask": jnp.zeros((B, S), bool).at[:, ::4].set(True),
            "targets": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        }
    if cfg.family == "vlm":
        P = cfg.num_patches
        return {
            "vision_embeds": jax.random.normal(KEY, (B, P, cfg.d_model)),
            "tokens": jax.random.randint(KEY, (B, S - P), 0, cfg.vocab_size),
            "targets": jax.random.randint(KEY, (B, S - P), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train(arch):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 32
    batch = make_smoke_batch(cfg, B, S)

    logits, aux = jax.jit(model.forward_train)(params, batch)
    text = batch.get("tokens", batch.get("frames"))
    expect_S = text.shape[1]
    assert logits.shape == (B, expect_S, cfg.vocab_size), arch
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert bool(jnp.isfinite(loss)), arch
    # one gradient step keeps everything finite
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)), arch


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).family != "audio"])
def test_arch_decode_consistency(arch):
    """prefill + decode_step must reproduce the teacher-forced logits."""
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(KEY, (B, cfg.num_patches,
                                                         cfg.d_model))
    full, _ = jax.jit(model.forward_train)(params, batch)

    cache = model.init_cache(B, 64)
    pre = dict(batch)
    pre["tokens"] = toks[:, :S - 1]
    logits_pre, cache = jax.jit(model.prefill)(params, pre, cache)
    logits_dec, cache = jax.jit(model.decode_step)(
        params, toks[:, S - 1:S], cache)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(full[:, -1]),
                               atol=5e-3, rtol=5e-3)


def test_grid_cells_and_skips():
    """The dry-run grid has the documented shape: 40 nominal, 9 skips."""
    grid = cells()
    assert len(grid) == 31
    skips = []
    for a in ARCHS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, reason = shape_applicable(cfg, s)
            if not ok:
                skips.append((a, s, reason))
    assert len(skips) == 9
    # encoder-only: no decode; full-attention: no long_500k
    assert ("hubert_xlarge", "decode_32k") in [(a, s) for a, s, _ in skips]
    assert ("zamba2_2p7b", "long_500k") not in [(a, s) for a, s, _ in skips]
    assert ("gemma2_9b", "long_500k") in [(a, s) for a, s, _ in skips]


@pytest.mark.parametrize("arch,shape", [("qwen2p5_3b", "train_4k"),
                                        ("zamba2_2p7b", "decode_32k"),
                                        ("hubert_xlarge", "prefill_32k")])
def test_input_specs_are_abstract(arch, shape):
    cfg = get_config(arch)
    spec = input_specs(cfg, shape)
    for leaf in jax.tree.leaves(spec):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_exact_published_configs():
    """Configs carry the exact published numbers from the assignment."""
    c = get_config("gemma2-9b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (42, 3584, 16, 8, 14336, 256000)
    c = get_config("zamba2-2.7b")
    assert (c.num_layers, c.d_model, c.ssm_state) == (54, 2560, 64)
    c = get_config("deepseek-v2-lite-16b")
    assert (c.num_experts, c.top_k, c.kv_lora_rank,
            c.num_shared_experts) == (64, 6, 512, 2)
    c = get_config("qwen2.5-3b")
    assert c.qkv_bias and c.vocab_size == 151936
    c = get_config("smollm-360m")
    assert (c.num_heads, c.num_kv_heads) == (15, 5)
    c = get_config("olmo-1b")
    assert c.norm == "layernorm_np"
    c = get_config("granite-moe-1b-a400m")
    assert (c.num_experts, c.top_k, c.vocab_size) == (32, 8, 49155)
    c = get_config("xlstm-125m")
    assert (c.num_layers, c.d_model, c.d_ff) == (12, 768, 0)
    c = get_config("hubert-xlarge")
    assert not c.causal and c.vocab_size == 504
    c = get_config("phi-3-vision-4.2b")
    assert c.frontend == "vision" and c.d_model == 3072
