"""Streaming control service: events, drift, delta solves, typed API.

Covers the ISSUE 9 tentpole and satellites:

* delta-solve parity: an all-dirty delta solve is *bit-identical* to the
  full sharded solve (property-tested over seeds and shard counts), and a
  strict-subset delta never worsens the global objective (the never-worse
  revert guard);
* the drift decision table (``service.drift``) row by row;
* shadow/event bookkeeping: dirty bits, membership, the applied-sequence
  integrity log;
* the service loop end-to-end (noop/delta/full behaviour, asyncio serve);
* the stale-advisory fix: deadlines that pass while the controller is held
  are expired explicitly, audited, and trigger one catch-up rebalance;
* the API redesign: ``step(TickInput) -> TickResult`` is the only entry
  point — the pre-PR-9 shims are gone and stale callers fail loudly.
"""

import asyncio
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BalanceController, ControllerConfig, CoopConfig,
                        TickInput, generate_cluster)
from repro.core.goals import objective
from repro.core.planner import CAPACITY, Advisory
from repro.service import (DELTA, FULL, NOOP, AdvisoryBatch, AppArrival,
                           AppDeparture, CapacityUpdate, DriftConfig,
                           DriftDetector, FaultSignal, FleetShadow,
                           ServiceConfig, ServiceLoop, TelemetryDelta)
from repro.shard import (FleetConfig, ShardSolveConfig, merge_assignment,
                         partition_problem, plan_shards, solve_fleet,
                         solve_shards)


def _cluster(num_apps=64, seed=0):
    return generate_cluster(num_apps=num_apps, seed=seed)


# ---------------------------------------------------------------------------
# delta-solve parity (the acceptance gate's hard property)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,num_shards", [(0, 2), (1, 3), (2, 4)])
def test_all_dirty_delta_bit_identical_to_full(seed, num_shards):
    cluster = _cluster(seed=seed)
    plan = plan_shards(cluster, num_shards)
    sharded = partition_problem(cluster.problem, plan)
    cfg = ShardSolveConfig(max_iters=48)
    full = solve_shards(sharded, cfg)
    for dirty in (np.ones(sharded.num_shards, bool),
                  np.arange(sharded.num_shards)):
        delta = solve_shards(sharded, cfg, dirty=dirty)
        # Bit-identical, not approximately equal: the all-dirty gather is
        # the identity, so the same jit executable sees the same inputs.
        assert np.array_equal(full.x, delta.x), (seed, num_shards)
        assert np.array_equal(full.iterations, delta.iterations)
        assert np.array_equal(full.objective, delta.objective)
        assert delta.solved.all()


def test_empty_dirty_set_returns_incumbents():
    cluster = _cluster()
    plan = plan_shards(cluster, 3)
    sharded = partition_problem(cluster.problem, plan)
    res = solve_shards(sharded, ShardSolveConfig(max_iters=16),
                       dirty=np.zeros(3, bool))
    assert np.array_equal(res.x, np.asarray(sharded.problems.assignment0))
    assert not res.solved.any()
    assert (res.iterations == 0).all()


def test_subset_delta_never_worse_than_incumbent():
    cluster = _cluster(seed=5)
    # Skew demand so a rebalance is actually worth something.
    p = cluster.problem
    rng = np.random.default_rng(5)
    skew = rng.uniform(0.6, 1.7, size=(p.num_apps, 1)).astype(np.float32)
    cluster = dataclasses.replace(
        cluster, problem=dataclasses.replace(
            p, demand=p.demand * jnp.asarray(skew)))
    obj0 = float(objective(cluster.problem, cluster.problem.assignment0))
    for dirty in ([0], [1, 2], [0, 3]):
        fd = solve_fleet(cluster, FleetConfig(num_shards=4),
                         dirty_shards=dirty)
        obj1 = float(objective(cluster.problem, jnp.asarray(fd.assignment)))
        # The never-worse guard: a scoped re-solve either improves the
        # global objective or reverts to the incumbent (audited).
        assert obj1 <= obj0 + 1e-6, dirty
        assert fd.timings["solved_shards"] == len(dirty)
        assert "delta_reverted" in fd.timings


def test_unsolved_shards_keep_incumbent_mapping():
    cluster = _cluster(seed=3)
    plan = plan_shards(cluster, 4)
    sharded = partition_problem(cluster.problem, plan)
    res = solve_shards(sharded, ShardSolveConfig(max_iters=32), dirty=[1])
    merged = merge_assignment(cluster.problem, sharded, res.x)
    x0 = np.asarray(cluster.problem.assignment0)
    untouched = plan.app_shard != 1
    assert np.array_equal(merged[untouched], x0[untouched])
    assert list(np.where(res.solved)[0]) == [1]


# ---------------------------------------------------------------------------
# drift decision table
# ---------------------------------------------------------------------------

def _decide(det, *, loads=None, now=0, capacity_dirty=False,
            outlook_active=False, stranded=0, dirty_shards=(),
            pending_membership=False, d2b=0.0):
    return det.decide(
        loads=np.asarray([0.5, 0.5, 0.5] if loads is None else loads),
        now=now, capacity_dirty=capacity_dirty,
        outlook_active=outlook_active, stranded=stranded,
        dirty_shards=dirty_shards, pending_membership=pending_membership,
        d2b=d2b)


def test_drift_table_full_triggers():
    det = DriftDetector()
    assert _decide(det, capacity_dirty=True).action == FULL
    assert _decide(det, outlook_active=True).action == FULL
    assert _decide(det, stranded=1).action == FULL
    assert _decide(det, loads=[0.4, 1.2, 0.5]).action == FULL  # overload
    assert _decide(det, d2b=0.3).action == FULL  # standing imbalance


def test_drift_table_quiescent_and_delta():
    det = DriftDetector(DriftConfig(d2b_delta=0.08))
    first = _decide(det)
    assert first.action == NOOP
    # Dirty apps alone are not enough below every threshold...
    calm = _decide(det, dirty_shards=(1,))
    assert calm.action == NOOP
    # ...but membership churn on a dirty shard is.
    move = _decide(det, dirty_shards=(1,), pending_membership=True)
    assert move.action == DELTA
    assert move.dirty_shards == (1,)
    # Mild standing imbalance above d2b_delta scopes to the dirty shards.
    mild = _decide(det, d2b=0.1, dirty_shards=(2,))
    assert mild.action == DELTA


def test_drift_solver_floor_masks_unfixable_imbalance():
    det = DriftDetector()
    # The last applied solve could only reach d2b 0.3: re-firing on the
    # same standing imbalance would burn a full pass every tick.
    det.note_solve(np.asarray([0.5, 0.5, 0.5]), full=True, d2b=0.3)
    assert _decide(det, d2b=0.3).action == NOOP
    # Real further drift above floor + margin still fires.
    assert _decide(det, d2b=0.4).action == FULL
    # The floor decays: after enough quiet ticks the detector re-probes
    # whether the solver can now do better.
    for _ in range(200):
        _decide(det, d2b=0.0)
    assert _decide(det, d2b=0.3).action == FULL


def test_drift_fault_holds_delta_not_full():
    det = DriftDetector()
    _decide(det)  # seed the EWMA
    det.note_fault(until=10)
    held = _decide(det, now=5, dirty_shards=(0,), pending_membership=True)
    assert held.action == NOOP and "fault" in held.reason
    # FULL triggers still fire on suspect data (feasibility beats caution).
    assert _decide(det, now=5, stranded=2).action == FULL
    # After the fault window the delta resumes.
    after = _decide(det, now=11, dirty_shards=(0,), pending_membership=True)
    assert after.action == DELTA


def test_drift_ewma_rebases_at_solve():
    det = DriftDetector(DriftConfig(ewma_alpha=1.0, full_threshold=0.5,
                                    overload_full=10.0))
    _decide(det, loads=[0.5, 0.5, 0.5])
    drifted = _decide(det, loads=[0.5, 0.66, 0.5], dirty_shards=(1,),
                      d2b=0.12)
    assert drifted.action == DELTA and drifted.divergence > 0.1
    det.note_solve(np.asarray([0.5, 0.66, 0.5]), full=True)
    rebased = _decide(det, loads=[0.5, 0.66, 0.5])
    assert rebased.action == NOOP and rebased.divergence == 0.0


def test_drift_full_interval_safety_valve():
    det = DriftDetector(DriftConfig(full_interval=3))
    assert [_decide(det).action for _ in range(3)] == [NOOP, NOOP, FULL]


# ---------------------------------------------------------------------------
# fleet shadow
# ---------------------------------------------------------------------------

def test_shadow_telemetry_dirty_bits_are_relative():
    cluster = _cluster()
    shadow = FleetShadow(cluster, dirty_rel=0.05)
    d = np.asarray(cluster.problem.demand)
    tasks = np.asarray(cluster.problem.tasks)
    # App 0 drifts 1% (clean), app 1 drifts 20% (dirty).
    ev = TelemetryDelta(app_ids=(0, 1),
                        demand=np.stack([d[0] * 1.01, d[1] * 1.2]),
                        tasks=tasks[:2], collected_at=7)
    shadow.apply(ev, seq=0)
    assert shadow.dirty_apps == {1}
    assert shadow.collected_at == 7
    shadow.clean([1])
    assert shadow.dirty_apps == set()
    # Re-based reference: the same reading again is no longer drift.
    shadow.apply(dataclasses.replace(ev, collected_at=8), seq=1)
    assert shadow.dirty_apps == set()


def test_shadow_membership_and_capacity():
    cluster = _cluster()
    shadow = FleetShadow(cluster)
    app = 0
    shadow.apply(AppDeparture(app_id=app), seq=0)
    assert not shadow._valid[app]
    shadow.apply(AppArrival(app_id=app, demand=[0.01, 0.01], tasks=2.0,
                            slo=1, tier=3), seq=1)
    assert shadow._valid[app] and shadow._x0[app] == 3
    assert not shadow.capacity_dirty
    shadow.apply(CapacityUpdate(
        capacity=np.asarray(cluster.problem.capacity) * 0.9), seq=2)
    assert shadow.capacity_dirty
    assert shadow.applied_seq[app] == [0, 1]


def test_shadow_view_roundtrip():
    cluster = _cluster()
    shadow = FleetShadow(cluster)
    view = shadow.view(now=42)
    assert view.collected_at == 42
    assert np.array_equal(np.asarray(view.problem.assignment0),
                          np.asarray(cluster.problem.assignment0))
    p, q = cluster.problem, view.problem
    live = np.asarray(p.valid)
    assert np.allclose(np.asarray(q.demand)[live], np.asarray(p.demand)[live])


# ---------------------------------------------------------------------------
# service loop
# ---------------------------------------------------------------------------

def test_loop_quiescent_ticks_are_noops():
    loop = ServiceLoop(_cluster())
    # The generated seed state is imbalanced on purpose: the first tick is
    # a full pass (standing spread), after which the fleet is quiescent.
    first = loop.step(0)
    assert first.action == FULL and first.applied
    rounds = loop.controller.round
    for t in range(1, 5):
        out = loop.step(t)
        assert out.action == NOOP, out.reason
        assert out.result is None
    s = loop.stats()
    assert s["noop_ticks"] == 4 and s["dropped_events"] == 0
    assert loop.controller.round == rounds  # no further solve priced


def test_loop_delta_then_full_and_integrity():
    cluster = _cluster()
    loop = ServiceLoop(cluster, config=ServiceConfig(num_shards=3))
    d = np.asarray(cluster.problem.demand)
    live = np.flatnonzero(np.asarray(cluster.problem.valid))
    # Localized drift: a handful of apps double their demand.
    ids = live[:5]
    loop.submit(TelemetryDelta(app_ids=tuple(int(i) for i in ids),
                               demand=d[ids] * 2.0,
                               tasks=np.asarray(cluster.problem.tasks)[ids],
                               collected_at=1))
    out = loop.step(1)
    assert out.action in (DELTA, FULL)
    assert out.events_drained == 1
    if out.action == DELTA:
        assert out.result is not None and out.result.delta
        assert 0 < len(out.dirty_shards) < loop.num_shards
    # Structural change forces a full pass through the global engine.
    loop.submit(CapacityUpdate(
        capacity=np.asarray(cluster.problem.capacity) * 0.85))
    out2 = loop.step(2)
    assert out2.action == FULL
    assert out2.result is not None and not out2.result.delta
    assert loop.dropped_events == 0
    assert loop.stats()["events_applied"] == loop.submitted


def test_loop_advisories_and_fault_route_to_controller():
    cluster = _cluster()
    loop = ServiceLoop(cluster)
    loop.submit(AdvisoryBatch(advisories=(
        Advisory(at=6, kind=CAPACITY, tier=0, scale=0.5),)))
    loop.submit(FaultSignal(source="telemetry", until=3, severity=0.4))
    out = loop.step(0)
    assert loop.controller.planner is not None
    assert loop.drift.fault_until == 3
    # The advisory is inside the horizon: the outlook forces a full pass.
    assert out.action == FULL


def test_loop_serve_drains_asyncio_queue():
    cluster = _cluster()
    loop = ServiceLoop(cluster)
    d = np.asarray(cluster.problem.demand)
    live = np.flatnonzero(np.asarray(cluster.problem.valid))

    async def drive():
        q = asyncio.Queue()
        for k in range(3):
            ids = live[k::8][:4]
            await q.put(TelemetryDelta(
                app_ids=tuple(int(i) for i in ids), demand=d[ids] * 1.01,
                tasks=np.asarray(cluster.problem.tasks)[ids],
                collected_at=k))
        await q.put(None)
        return await loop.serve(q)

    steps = asyncio.run(drive())
    assert steps >= 1
    assert loop.applied_events == 3 and loop.dropped_events == 0


# ---------------------------------------------------------------------------
# stale-advisory fix
# ---------------------------------------------------------------------------

def test_stale_advisory_expires_and_forces_catchup():
    cluster = _cluster()
    # Thresholds high enough that nothing triggers organically: the only
    # way this controller rebalances is the catch-up path under test.
    ctl = BalanceController(cluster, ControllerConfig(
        timeout_s=4, trigger_d2b=9.0, trigger_over_ideal=9.0,
        trigger_slo_apps=10**6))
    ctl.ingest(AdvisoryBatch(advisories=(
        Advisory(at=2, kind=CAPACITY, tier=0, scale=0.5),)))
    # The controller never gets to act before the deadline passes (no tick
    # runs): at now=3 the advisory is stale.  Expiry must be explicit and
    # the unacted deadline must force one catch-up rebalance.
    res = ctl.step(TickInput(now=3))
    assert len(res.expired_advisories) == 1
    assert res.expired_advisories[0]["acted"] is False
    assert res.triggered and "expired-advisory catch-up" in res.reason
    audit = ctl.audit()
    assert audit["advisories_expired_unacted"] == 1
    assert audit["advisory_expiries"][0]["at"] == 2
    # The catch-up fires once, not forever.
    res2 = ctl.step(TickInput(now=4))
    assert "expired-advisory catch-up" not in res2.reason


def test_acted_advisory_expires_without_catchup():
    cluster = _cluster()
    ctl = BalanceController(cluster, ControllerConfig(
        timeout_s=4, trigger_d2b=0.0, cooldown_rounds=0))
    ctl.ingest(AdvisoryBatch(advisories=(
        Advisory(at=8, kind=CAPACITY, tier=0, scale=0.5),)))
    # trigger_d2b=0 fires a rebalance at now=1 with the advisory inside
    # the planning horizon -> acted.
    res = ctl.step(TickInput(now=1))
    assert res.triggered
    res2 = ctl.step(TickInput(now=9))
    expired = res2.expired_advisories
    assert len(expired) == 1 and expired[0]["acted"] is True
    assert "expired-advisory catch-up" not in res2.reason


# ---------------------------------------------------------------------------
# API redesign: step/TickInput is the only entry point
# ---------------------------------------------------------------------------

def test_tickresult_delegates_to_event():
    ctl = BalanceController(_cluster(), ControllerConfig(timeout_s=4))
    res = ctl.step(TickInput(now=0))
    assert res.event is not None
    assert res.applied == res.event.applied
    assert res.d2b_before == res.event.d2b_before
    assert res.mode == res.event.mode
    with pytest.raises(AttributeError):
        res.not_a_field


def test_legacy_entry_points_removed():
    """The pre-PR-9 shims are gone for good — the typed API is the only
    surface, so a stale caller fails loudly instead of silently warning."""
    cluster = _cluster()
    ctl = BalanceController(cluster, ControllerConfig(timeout_s=4))
    for legacy in ("tick", "observe", "set_advisories", "admit"):
        assert not hasattr(ctl, legacy), legacy
    # The internal equivalents the typed API routes through still exist.
    for private in ("_observe", "_set_advisories", "_admit"):
        assert hasattr(ctl, private), private


def test_ingest_membership_mutates_standalone_cluster():
    cluster = _cluster()
    ctl = BalanceController(cluster, ControllerConfig(timeout_s=4))
    app = 0
    ctl.ingest(AppDeparture(app_id=app))
    assert not bool(ctl.cluster.problem.valid[app])
    ctl.ingest(AppArrival(app_id=app, demand=[0.02, 0.02], tasks=3.0,
                          slo=0, tier=1))
    assert bool(ctl.cluster.problem.valid[app])
    assert int(ctl.cluster.problem.assignment0[app]) == 1
    with pytest.raises(ValueError):
        ctl.ingest(object())


def test_ingest_fault_degrades_composite_score():
    cluster = _cluster()
    ctl = BalanceController(cluster, ControllerConfig(timeout_s=4))
    base = ctl._composite_score()
    ctl.now = 0
    ctl.ingest(FaultSignal(source="upstream", until=5, severity=0.5))
    assert ctl._composite_score() == pytest.approx(base * 0.5)
    ctl.now = 6  # expired: pruned on the next score
    assert ctl._composite_score() == pytest.approx(base)
