"""Hierarchy co-operation (paper §3.4 / Fig. 2): variants, feedback,
convergence, and the Fig. 4/5 qualitative trade-offs."""
import numpy as np
import pytest

from repro.core import (CoopConfig, HostScheduler, RegionScheduler, Sptlb,
                        generate_cluster)
from repro.core.hierarchy import region_overlap_avoid


@pytest.fixture(scope="module")
def cluster():
    return generate_cluster(num_apps=300, seed=0)


@pytest.fixture(scope="module")
def decisions(cluster):
    s = Sptlb(cluster)
    return {v: s.balance("local", timeout_s=30,
                         config=CoopConfig(variant=v, max_rounds=20))
            for v in ("no_cnst", "w_cnst", "manual_cnst")}


def test_all_variants_feasible(cluster, decisions):
    for v, d in decisions.items():
        assert d.violations.ok, v


def test_manual_cnst_converges_to_acceptance(decisions):
    d = decisions["manual_cnst"]
    assert d.cooperation.accepted
    assert d.cooperation.feedback_rounds >= 2      # feedback actually looped
    assert d.cooperation.num_rejections > 0        # and learned constraints


def test_network_latency_ordering(decisions):
    """Fig. 4: no_cnst worst; w_cnst & manual_cnst comparable and better."""
    no = decisions["no_cnst"].network_p99_ms
    w = decisions["w_cnst"].network_p99_ms
    man = decisions["manual_cnst"].network_p99_ms
    assert no > w
    assert no > man
    assert man <= no * 0.8


def test_manual_beats_wcnst_on_balance(decisions):
    """Fig. 5: manual_cnst dominates w_cnst on solution quality."""
    assert (decisions["manual_cnst"].difference_to_balance
            <= decisions["w_cnst"].difference_to_balance + 1e-6)


def test_manual_rejections_respected(cluster):
    """Every accepted move in the final mapping passes the region check."""
    s = Sptlb(cluster)
    d = s.balance("local", config=CoopConfig(max_rounds=20))
    region = RegionScheduler(cluster)
    x = np.asarray(d.assignment)
    x0 = np.asarray(cluster.problem.assignment0)
    for n in np.where(x != x0)[0]:
        assert region.check(int(n), int(x[n]))


def test_host_scheduler_rejects_oversized():
    cluster = generate_cluster(num_apps=50, seed=1)
    host = HostScheduler(cluster)
    # an app bigger than any host must be rejected
    demand = np.asarray(cluster.problem.demand)
    big = int(np.argmax(demand[:, 0]))
    cluster.problem.demand.at[big].set(cluster.host_capacity.max() * 10)
    # direct check on a synthetic overload: all apps into tier 0
    apps = np.arange(50)
    rejected = host.check_tier(0, apps)
    assert isinstance(rejected, list)


def test_wcnst_is_static_avoid(cluster):
    avoid = region_overlap_avoid(cluster)
    assert avoid.shape == (cluster.problem.num_apps, cluster.problem.num_tiers)
    # staying home is never forbidden by w_cnst
    x0 = np.asarray(cluster.problem.assignment0)
    assert not avoid[np.arange(len(x0)), x0].any()


def test_restart_rounds_never_worse_and_vetted(cluster):
    """ROADMAP follow-up: the premasked path gets the diversification that
    rejection rounds used to provide, as explicit perturbation restarts.
    Candidates are re-vetted, and only adopted on objective improvement —
    so the knob can spend solves but never quality or feasibility."""
    s = Sptlb(cluster)
    d0 = s.balance("local", timeout_s=30, config=CoopConfig(max_rounds=20))
    d1 = s.balance("local", timeout_s=30,
                   config=CoopConfig(max_rounds=20, restart_rounds=3))
    assert d1.solve.objective <= d0.solve.objective + 1e-5
    assert d1.violations.ok
    tm = d1.cooperation.timings
    assert 0 < tm["restarts"] <= 3
    assert 0 <= tm["restart_improved"] <= tm["restarts"]
    # restart-adopted moves still pass the region vet
    region = RegionScheduler(cluster)
    x = np.asarray(d1.assignment)
    x0 = np.asarray(cluster.problem.assignment0)
    moved = np.where(x != x0)[0]
    assert region.check_many(moved, x[moved]).all()


def test_check_tiers_force_packs_returner_tier():
    """ROADMAP gap: a home tier whose only change is returning apps (no
    movers to vet) must be re-packed instead of trusted to absorb them.
    ``force_tiers`` packs it and surfaces residents that fail."""
    import dataclasses
    cluster = generate_cluster(num_apps=50, seed=1)
    # shrink tier 0 to a single host so its own residents cannot pack
    hosts = cluster.hosts_per_tier.copy()
    hosts[0] = 1
    x0 = np.zeros(50, np.int64)              # everyone lives in tier 0
    cluster = dataclasses.replace(cluster, hosts_per_tier=hosts)
    host = HostScheduler(cluster)
    # no movers at all: the legacy call has nothing to pack...
    assert host.check_tiers(x0, x0, np.empty(0, np.int64)).size == 0
    assert host.resident_overflows == 0
    # ...but the force re-pack vets the tier and counts the overflow
    rej = host.check_tiers(x0, x0, np.empty(0, np.int64),
                           force_tiers=np.array([0]))
    assert rej.size == 0                     # residents never bounce
    assert host.resident_overflows > 0       # the overflow is observable


def test_greedy_engine_through_sptlb(cluster):
    d = Sptlb(cluster).balance("greedy-cpu")
    # Greedy honours the movement budget and SLO table but is capacity-naive
    # (it may overfill the destination tier — part of why SPTLB exists).
    assert not d.violations.move_budget_exceeded
    assert not d.violations.slo_violated
    assert d.cooperation is None                    # greedy is hierarchy-blind
