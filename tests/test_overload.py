"""Edge cases for the overload control plane (ISSUE 7 satellite).

The fuzz suite (tests/test_fuzz_scenarios.py) sweeps random trajectories;
this file pins the corners with hand-built fixtures:

  * a zero-capacity fleet defers every arrival (and backs off),
  * step curves reproduce the binary SLO table exactly,
  * the shedder does nothing while capacity suffices (and without curves),
  * SAFE mode rejects non-critical arrivals and only those,
  * hysteresis: re-admission waits ``readmit_ticks`` consecutive margin
    ticks and an oscillating load never flaps caps back on.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import generate_cluster
from repro.core.shedding import LoadShedder, ShedConfig
from repro.core.utility import (
    attach_curves,
    delivered_fractions,
    fleet_utility,
    step_curves,
    utility_of,
)
from repro.streams.admission import AdmissionController, AdmissionState

# ---------------------------------------------------------------------------
# admission corners
# ---------------------------------------------------------------------------


def _zero_capacity_problem():
    problem = generate_cluster(num_apps=16, seed=0).problem
    return dataclasses.replace(problem, capacity=jnp.zeros_like(problem.capacity))


def test_zero_capacity_fleet_defers_everything():
    """No headroom anywhere: every arrival defers, none admits (not even
    degraded), and per-app backoff grows exponentially across retries."""
    problem = _zero_capacity_problem()
    gate = AdmissionController()
    with np.errstate(divide="ignore", invalid="ignore"):
        retries = [
            gate.decide(
                problem,
                demand=np.array([0.05, 0.03]),
                tasks=4.0,
                slo=0,
                criticality=0.5,
                key="stuck",
                now=t,
            ).retry_after
            for t in range(4)
        ]
        other = gate.decide(
            problem,
            demand=np.array([0.01, 0.01]),
            tasks=1.0,
            slo=2,
            criticality=1.0,
            key="other",
            now=0,
        )
    assert all(d.state is AdmissionState.DEFER for d in gate.log)
    assert retries == [1, 2, 4, 8]  # backoff_base ** attempts
    assert other.retry_after == 1  # backoff is per app key
    audit = gate.audit()
    assert audit["defer"] == audit["decisions"] == 5
    assert audit["admit"] == audit["admit_degraded"] == 0
    assert audit["backlog"] == 2


def test_safe_mode_rejects_non_critical_only():
    """SAFE refuses arrivals below the critical floor outright (no retry
    hint); at-or-above-floor arrivals are still priced normally."""
    problem = generate_cluster(num_apps=32, seed=1).problem
    gate = AdmissionController()
    low = gate.decide(
        problem,
        demand=np.array([0.01, 0.01]),
        tasks=1.0,
        slo=0,
        criticality=0.3,
        key="low",
        mode="safe",
    )
    assert low.state is AdmissionState.REJECT
    assert low.reason.startswith("safe-mode")
    assert low.retry_after == 0
    high = gate.decide(
        problem,
        demand=np.array([0.01, 0.01]),
        tasks=1.0,
        slo=0,
        criticality=gate.config.critical_floor,
        key="high",
        mode="safe",
    )
    assert high.state is not AdmissionState.REJECT


# ---------------------------------------------------------------------------
# step-curve parity with the binary SLO table
# ---------------------------------------------------------------------------


def test_step_curve_is_the_binary_table_pointwise():
    """slope=inf makes u(d) the exact indicator weight * [d >= knee]."""
    knee, slope, weight = (jnp.asarray(a) for a in step_curves([0.0, 0.5, 1.0]))
    for d in (0.0, 0.25, 0.999, 1.0):
        u = np.asarray(utility_of(jnp.full(3, d), knee, slope, weight))
        want = np.where(d >= 1.0, np.asarray(weight), 0.0)
        np.testing.assert_allclose(u, want)


def test_step_curve_fleet_utility_matches_binary_accounting():
    """Fleet utility under step curves == the binary table's satisfied-app
    weight: an app earns its full weight iff delivered >= knee, else zero —
    on a fleet loaded past capacity so both branches are exercised."""
    problem = generate_cluster(num_apps=96, seed=7).problem
    problem = dataclasses.replace(problem, demand=problem.demand * 2.0)
    problem = attach_curves(problem, step=True)
    x0 = problem.assignment0
    delivered = np.asarray(delivered_fractions(problem, x0))
    valid = np.asarray(problem.valid, bool)
    satisfied = valid & (delivered >= np.asarray(problem.util_knee))
    assert satisfied.any() and (valid & ~satisfied).any()
    got, max_u = fleet_utility(problem, x0)
    want = float(np.asarray(problem.util_weight)[satisfied].sum())
    np.testing.assert_allclose(float(got), want, rtol=1e-5)
    np.testing.assert_allclose(
        float(max_u), float(np.asarray(problem.util_weight)[valid].sum()), rtol=1e-5
    )


# ---------------------------------------------------------------------------
# shedder corners
# ---------------------------------------------------------------------------


def _shed_problem(scale: float):
    """8 equal apps, each demanding ``scale/8`` of total fleet capacity per
    resource: offered load is exactly ``scale`` x capacity, criticality 0
    so every app is sheddable and curves are uniform."""
    problem = generate_cluster(num_apps=8, seed=2).problem
    total = np.asarray(problem.capacity, np.float64).sum(axis=0)
    demand = np.tile(total * (scale / 8.0), (8, 1)).astype(np.float32)
    problem = dataclasses.replace(
        problem, demand=jnp.asarray(demand), criticality=jnp.zeros(8, jnp.float32)
    )
    return attach_curves(problem)


def test_shed_set_empty_when_capacity_suffices():
    shedder = LoadShedder()
    plan = shedder.plan(_shed_problem(0.5))
    assert not plan.active
    assert plan.shed_ids == () and plan.readmitted_ids == ()
    assert plan.churn_cost == 0.0
    assert plan.overload_frac <= 1.0
    np.testing.assert_array_equal(plan.caps, np.ones(8, np.float32))
    assert shedder.shed_events == 0


def test_shedder_refuses_to_act_without_curves():
    """Overloaded but curve-less: no utility order means no shed order —
    the plan stays inert rather than shedding arbitrarily."""
    problem = generate_cluster(num_apps=8, seed=2).problem
    problem = dataclasses.replace(problem, demand=problem.demand * 50.0)
    assert not problem.has_utility
    plan = LoadShedder().plan(problem)
    assert not plan.active and plan.shed_ids == ()


def test_overload_sheds_until_served_fits():
    shedder = LoadShedder()
    plan = shedder.plan(_shed_problem(1.5), now=3)
    # Each shed frees 0.75 * 1.5/8 of capacity; removing the 0.5 excess
    # takes four apps.
    assert len(plan.shed_ids) == 4
    assert plan.active and plan.overload_frac > 1.0
    assert shedder.shed_events == 4
    capped = plan.caps < 1.0
    assert capped.sum() == 4
    np.testing.assert_allclose(plan.caps[capped], shedder.config.min_delivered)
    # SHED advisories ride the declared-event channel, one per transition.
    assert len(plan.advisories) == 4
    assert all(a.at == 3 for a in plan.advisories)


def test_hysteresis_readmits_only_after_consecutive_margin_ticks():
    cfg = ShedConfig()
    shedder = LoadShedder(cfg)
    assert len(shedder.plan(_shed_problem(1.5)).shed_ids) == 4
    calm = _shed_problem(0.3)
    for tick in range(cfg.readmit_ticks - 1):
        plan = shedder.plan(calm)
        assert plan.readmitted_ids == (), tick
        assert plan.active
    plan = shedder.plan(calm)  # the readmit_ticks-th margin tick
    assert len(plan.readmitted_ids) == 4
    assert not plan.active
    np.testing.assert_array_equal(plan.caps, np.ones(8, np.float32))
    assert shedder.readmit_events == 4


def test_oscillating_load_never_flaps_caps():
    """Load that keeps dipping below the margin but bouncing back above it
    (while staying under capacity) resets the streak every time: the caps
    never lift, however long it oscillates."""
    cfg = ShedConfig()
    shedder = LoadShedder(cfg)
    assert shedder.plan(_shed_problem(1.5)).active
    calm = _shed_problem(0.3)
    # served = (4 + 4 * 0.25)/8 * 1.5 = 0.9375 of capacity: under target,
    # above the 0.92 re-admission margin — the streak-reset band.
    bouncy = _shed_problem(1.5)
    for _ in range(3):
        for problem in (calm, calm, bouncy):
            plan = shedder.plan(problem)
            assert plan.readmitted_ids == ()
            assert plan.active
    assert shedder.readmit_events == 0
    assert (plan.caps < 1.0).sum() == 4
