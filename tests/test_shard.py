"""Sharded fleet solver (PR 8): partition/merge bijection, single-shard
golden parity with the global solver, decomposable multi-shard parity,
coordinator vetting + priced boundary migrations, and the controller's
``shards`` routing."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    LocalSearchConfig,
    Sptlb,
    generate_cluster,
    pad_problem,
    solve_local,
)
from repro.core.controller import (BalanceController, ControllerConfig,
                                   TickInput)
from repro.core.goals import objective
from repro.core.levels import CoopConfig, Proposal, level_factory
from repro.core.problem import tier_loads
from repro.shard import (
    FleetConfig,
    FleetCoordinator,
    balance_fleet,
    merge_assignment,
    partition_problem,
    plan_shards,
    shard_utilization,
    solve_fleet,
    solve_shards,
    stranded_apps,
    synthetic_fleet,
    tier_anchors,
)
from _hypothesis_compat import hypothesis, st


@pytest.fixture(scope="module")
def cluster():
    return generate_cluster(num_apps=120, seed=3)


# -- partitioning ------------------------------------------------------------


def test_plan_shards_covers_every_tier_exactly_once(cluster):
    plan = plan_shards(cluster, 3)
    T = cluster.problem.num_tiers
    all_tiers = np.sort(np.concatenate(plan.shard_tiers))
    np.testing.assert_array_equal(all_tiers, np.arange(T))
    x0 = np.asarray(cluster.problem.assignment0)
    np.testing.assert_array_equal(plan.app_shard, plan.tier_shard[x0])
    # every shard owns >= 1 tier; S clamps to [1, T]
    assert all(len(ts) >= 1 for ts in plan.shard_tiers)
    assert plan_shards(cluster, 10 * T).num_shards == T
    assert plan_shards(cluster, 0).num_shards == 1


def test_tier_anchors_follow_region_arcs():
    tr = np.zeros((4, 8), bool)
    tr[0, 2:5] = True  # arc starting at region 2
    tr[1, 6:] = True
    tr[1, 0] = True  # wrap-around arc starting at 6
    tr[2, :] = True  # degenerate: everywhere -> 0
    np.testing.assert_array_equal(tier_anchors(tr), [2, 6, 0, 0])


@hypothesis.given(
    st.integers(40, 160), st.integers(1, 6), st.integers(0, 5)
)
@hypothesis.settings(max_examples=12, deadline=None, derandomize=True)
def test_partition_merge_is_a_bijection(num_apps, num_shards, seed):
    """Every app lands in exactly one shard slot, and merging the stacked
    local incumbents returns the global assignment0 bit-for-bit."""
    cl = generate_cluster(num_apps=num_apps, seed=seed)
    plan = plan_shards(cl, num_shards)
    sharded = partition_problem(cl.problem, plan)
    ids = sharded.app_ids[sharded.app_ids >= 0]
    np.testing.assert_array_equal(np.sort(ids), np.arange(num_apps))
    local_x0 = np.asarray(sharded.problems.assignment0)
    merged = merge_assignment(cl.problem, sharded, local_x0)
    np.testing.assert_array_equal(merged, np.asarray(cl.problem.assignment0))
    assert stranded_apps(cl.problem, merged) == 0


def test_partition_pads_inert_tiers(cluster):
    plan = plan_shards(cluster, 4)
    sharded = partition_problem(cluster.problem, plan)
    widths = [len(ts) for ts in plan.shard_tiers]
    assert sharded.tier_bucket == max(widths)
    slo_allowed = np.asarray(sharded.problems.slo_allowed)
    avoid = np.asarray(sharded.problems.avoid)
    for s, w in enumerate(widths):
        assert (sharded.tier_ids[s, w:] == -1).all()
        # inert tiers: no SLO class allowed, avoided by every real app
        # (pad_problem's inert app rows are neutralized by valid=False)
        assert not slo_allowed[s, w:].any()
        real = sharded.app_ids[s] >= 0
        assert avoid[s, real, w:].all()


# -- solve parity ------------------------------------------------------------


def test_single_shard_solve_matches_global_golden():
    """S=1 partitioning is the identity (tiers sorted ascending, defaults
    matching ``LocalSearchConfig``), so the sharded pass must reproduce the
    global solver's assignment exactly — the golden parity pin."""
    cl = generate_cluster(num_apps=100, seed=0)
    plan = plan_shards(cl, 1)
    sharded = partition_problem(cl.problem, plan)
    res = solve_shards(sharded)
    merged = merge_assignment(cl.problem, sharded, res.x)

    ref = solve_local(
        pad_problem(cl.problem),
        LocalSearchConfig(max_iters=256, batch_moves=16),
    )
    n = cl.problem.num_apps
    np.testing.assert_array_equal(merged, np.asarray(ref.assignment)[:n])
    assert float(objective(cl.problem, jnp.asarray(merged))) == pytest.approx(
        float(ref.objective), rel=1e-6
    )


def test_multi_shard_parity_when_problem_decomposes(cluster):
    """With feasibility confined to each app's home shard the global and
    sharded searches range over the same space — objectives must agree
    within a small tolerance and both must improve on the incumbent."""
    p = cluster.problem
    plan = plan_shards(cluster, 2)
    cross = plan.tier_shard[None, :] != plan.app_shard[:, None]
    p2 = dataclasses.replace(p, avoid=jnp.asarray(np.asarray(p.avoid) | cross))

    sharded = partition_problem(p2, plan)
    res = solve_shards(sharded)
    merged = merge_assignment(p2, sharded, res.x)
    obj_sharded = float(objective(p2, jnp.asarray(merged)))

    ref = solve_local(
        pad_problem(p2), LocalSearchConfig(max_iters=256, batch_moves=16)
    )
    obj_global = float(ref.objective)
    obj_start = float(objective(p2, p2.assignment0))

    assert stranded_apps(p2, merged) == 0
    assert obj_sharded < obj_start
    # Per-shard solves balance against shard-local tier sets, so the merged
    # objective tracks (not equals) the global optimum on the same space.
    assert obj_sharded == pytest.approx(obj_global, rel=0.15, abs=1e-3)
    # no-cross-shard-demand invariant: the merged mapping never crosses
    x0 = np.asarray(p2.assignment0)
    moved = merged != x0
    assert (plan.tier_shard[merged[moved]] == plan.app_shard[moved]).all()


def test_solve_fleet_end_to_end(cluster):
    fd = solve_fleet(cluster, FleetConfig(num_shards=3, timeout_s=30))
    p = cluster.problem
    assert fd.stranded == 0
    assert fd.objective <= float(objective(p, p.assignment0)) + 1e-6
    assert fd.apps_per_s > 0
    assert 0.0 <= fd.coordinator_overhead_frac <= 1.0
    assert set(fd.timings) == {
        "partition_s",
        "solve_s",
        "merge_s",
        "coordinator_s",
        "total_s",
        "solved_shards",
        "delta_reverted",
    }
    assert fd.timings["solved_shards"] == 3
    assert fd.timings["delta_reverted"] is False


# -- coordinator -------------------------------------------------------------


def test_premask_blocks_cross_shard_but_never_home(cluster):
    coord = FleetCoordinator(cluster, num_shards=3)
    mask = coord.premask(cluster.problem)
    n = cluster.problem.num_apps
    x0 = np.asarray(cluster.problem.assignment0)
    assert mask.shape == (n, cluster.problem.num_tiers)
    assert not mask[np.arange(n), x0].any()  # home tier always open
    cross = coord.plan.tier_shard[None, :] != coord.plan.app_shard[:, None]
    np.testing.assert_array_equal(mask, cross)


def test_vet_rejects_ungranted_cross_shard_moves(cluster):
    coord = FleetCoordinator(cluster, num_shards=2)
    plan = coord.plan
    x0 = np.asarray(cluster.problem.assignment0).astype(np.int64)
    # one same-shard move, one cross-shard move
    same = int(np.where(plan.app_shard == 0)[0][0])
    cross = int(np.where(plan.app_shard == 0)[0][1])
    x = x0.copy()
    x[same] = int(plan.shard_tiers[0][-1])
    x[cross] = int(plan.shard_tiers[1][0])
    prop = Proposal(
        x=x, x0=x0, candidates=np.asarray([same, cross], np.int64)
    )
    rejected = coord.vet(prop)
    np.testing.assert_array_equal(rejected, [cross])
    assert coord.counters()["rejected_cross_shard"] == 1
    # a standing grant flips the verdict
    coord._granted[cross, x[cross]] = True
    assert coord.vet(prop).size == 0


def test_plan_migrations_prices_and_grants(cluster):
    p = cluster.problem
    x0 = np.asarray(p.assignment0)
    plan = plan_shards(cluster, 2)
    util = shard_utilization(plan, p, x0)
    threshold = float(util.max()) - 1e-6  # exactly one shard saturated
    coord = FleetCoordinator(cluster, plan=plan, saturation=threshold)
    moves = coord.plan_migrations(p, x0)
    assert moves, "saturated shard must shed at least one donor"
    hot = int(np.argmax(util))
    feas = np.asarray(p.feasible_mask())
    for a, t in moves:
        assert plan.app_shard[a] == hot
        assert plan.tier_shard[t] != hot
        assert feas[a, t]
    assert coord.counters()["granted"] == len(moves)
    # granted moves now pass the bus vet
    x = x0.astype(np.int64).copy()
    apps = np.asarray([a for a, _ in moves], np.int64)
    x[apps] = [t for _, t in moves]
    assert coord.vet(Proposal(x=x, x0=x0.astype(np.int64), candidates=apps)).size == 0
    # a zero budget buys zero moves
    coord0 = FleetCoordinator(cluster, plan=plan, saturation=threshold)
    assert coord0.plan_migrations(p, x0, cost_budget=0.0) == []
    # max_moves caps the grant count
    coord1 = FleetCoordinator(cluster, plan=plan, saturation=threshold)
    assert len(coord1.plan_migrations(p, x0, max_moves=1)) <= 1


def test_fleet_level_registered_on_the_bus(cluster):
    assert level_factory("fleet") is FleetCoordinator
    balancer = Sptlb(cluster)
    decision = balancer.balance(
        "local",
        timeout_s=30,
        config=CoopConfig(levels=("region", "host", "fleet")),
    )
    assert decision.cooperation is not None
    assert "fleet" in decision.cooperation.timings.levels
    assert stranded_apps(cluster.problem, np.asarray(decision.assignment)) == 0


# -- controller + BalanceDecision contract -----------------------------------


def test_balance_fleet_decision_contract(cluster):
    decision = balance_fleet(
        cluster, fleet=FleetConfig(num_shards=2, timeout_s=30)
    )
    assert decision.cooperation is None
    sharded = decision.solve.extra["sharded"]
    assert sharded["num_shards"] == 2
    assert sharded["stranded"] == 0
    assert decision.solve.iterations >= 1  # never reads as a dead solver
    assert "balance_timings" in decision.solve.extra
    assert decision.movement_cost >= 0.0


def test_balance_fleet_respects_zero_movement_budget(cluster):
    n = cluster.problem.num_apps
    decision = balance_fleet(
        cluster,
        fleet=FleetConfig(num_shards=2, timeout_s=30),
        coop=CoopConfig(cost_budget=0.0, move_cost=np.ones(n, np.float32)),
    )
    np.testing.assert_array_equal(
        np.asarray(decision.assignment),
        np.asarray(cluster.problem.assignment0),
    )
    assert decision.movement_cost == pytest.approx(0.0)


def test_controller_routes_through_sharded_path(cluster):
    ctl = BalanceController(
        cluster,
        ControllerConfig(
            shards=2,
            timeout_s=30,
            cooldown_rounds=1,
            trigger_d2b=0.0,
            trigger_over_ideal=0.0,
        ),
    )
    ev = ctl.step(TickInput()).event
    assert ev.triggered and ev.applied
    assert ctl.audit()["rebalances"] == 1
    assert (
        stranded_apps(
            ctl.cluster.problem, np.asarray(ctl.cluster.problem.assignment0)
        )
        == 0
    )


# -- synthetic fleet generator ----------------------------------------------


def test_synthetic_fleet_is_well_formed():
    cl = synthetic_fleet(5_000, num_tiers=12, num_regions=8, seed=1)
    p = cl.problem
    assert p.num_apps == 5_000 and p.num_tiers == 12
    assert bool(np.asarray(p.valid).all())
    assert stranded_apps(p, np.asarray(p.assignment0)) == 0
    assert (np.asarray(p.capacity) > 0).all()
    util, _ = tier_loads(p, np.asarray(p.assignment0))
    frac = np.asarray(util) / np.asarray(p.capacity)
    assert 0.2 < float(frac.mean()) < 0.9  # near the util_target calibration
