"""Serving engine, gradient compression, continuous controller."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import generate_cluster
from repro.core.controller import (BalanceController, ControllerConfig,
                                   TickInput)
from repro.distributed.compress import GradCompressor
from repro.launch.serve import Request, RequestQueue, main as serve_main


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_request_queue_slo_priority():
    q = RequestQueue()
    q.push(Request(0, np.zeros(4, np.int32), slo=3, max_new_tokens=4))
    q.push(Request(1, np.zeros(4, np.int32), slo=0, max_new_tokens=4))
    q.push(Request(2, np.zeros(4, np.int32), slo=1, max_new_tokens=4))
    assert q.pop().rid == 1          # SLO1 served first
    assert q.pop().rid == 2
    assert q.pop().rid == 0


def test_serve_engine_end_to_end():
    report = serve_main(["--arch", "smollm-360m", "--requests", "10",
                         "--slots", "4", "--prompt-len", "8",
                         "--max-new", "6"])
    assert sum(s["n"] for s in report.values()) == 10
    for stats in report.values():
        assert stats["total_p99_ms"] > 0


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,wire_frac,tol", [
    ("bf16", 0.5, 6e-3), ("int8", 0.27, 3e-2)])
def test_compression_roundtrip_and_wire(mode, wire_frac, tol):
    comp = GradCompressor(mode=mode)
    rng = np.random.default_rng(0)
    grads = {"a": jnp.asarray(rng.normal(0, 0.02, (256, 128)), jnp.float32),
             "b": jnp.asarray(rng.normal(0, 1.0, (1000,)), jnp.float32)}
    state = comp.init_state(grads)
    c, state = comp.compress(grads, state)
    d = comp.decompress(c)
    for k in grads:
        scale = float(jnp.max(jnp.abs(grads[k]))) + 1e-9
        err = float(jnp.max(jnp.abs(d[k] - grads[k]))) / scale
        assert err < tol, (k, err)
    assert comp.wire_bytes(grads) <= wire_frac * 4 * sum(
        g.size for g in jax.tree.leaves(grads)) * 1.05


def test_error_feedback_removes_bias():
    """Mean compressed gradient over many steps ~ mean true gradient."""
    comp = GradCompressor(mode="int8")
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(0, 1e-3, (512,)), jnp.float32)
    state = comp.init_state({"g": g_true})
    acc = jnp.zeros_like(g_true)
    steps = 50
    for _ in range(steps):
        c, state = comp.compress({"g": g_true}, state)
        acc = acc + comp.decompress(c)["g"]
    bias = float(jnp.max(jnp.abs(acc / steps - g_true)))
    assert bias < 5e-5              # residual carried, not lost


def test_none_mode_is_identity():
    comp = GradCompressor(mode="none")
    grads = {"g": jnp.arange(8.0)}
    state = comp.init_state(grads)
    c, state = comp.compress(grads, state)
    np.testing.assert_array_equal(np.asarray(comp.decompress(c)["g"]),
                                  np.asarray(grads["g"]))


# ---------------------------------------------------------------------------
# continuous controller
# ---------------------------------------------------------------------------

def test_controller_triggers_and_applies():
    cluster = generate_cluster(num_apps=200, seed=5)
    ctl = BalanceController(cluster, ControllerConfig(cooldown_rounds=2))
    ev = ctl.step(TickInput()).event
    assert ev.triggered                      # tier 3 is hot by construction
    assert ev.applied
    assert ev.d2b_after < ev.d2b_before


def test_controller_cooldown_and_hysteresis():
    cluster = generate_cluster(num_apps=200, seed=5)
    ctl = BalanceController(cluster, ControllerConfig(cooldown_rounds=5))
    ev1 = ctl.step(TickInput()).event
    assert ev1.applied
    ev2 = ctl.step(TickInput()).event                         # inside cooldown
    assert not ev2.triggered and "cooldown" in ev2.reason
    audit = ctl.audit()
    assert audit["rebalances"] == 1
    assert audit["mean_improvement"] > 0


def test_controller_dry_run_does_not_mutate():
    cluster = generate_cluster(num_apps=150, seed=6)
    before = np.asarray(cluster.problem.assignment0).copy()
    ctl = BalanceController(cluster,
                            ControllerConfig(dry_run=True))
    ev = ctl.step(TickInput()).event
    assert ev.triggered and not ev.applied
    np.testing.assert_array_equal(
        np.asarray(ctl.cluster.problem.assignment0), before)


def test_compressed_psum_across_devices():
    """Compressed gradient reduction over a real (subprocess) 4-device mesh:
    psum(decompress(compress(g_i))) ~ psum(g_i)."""
    import os, subprocess, sys, textwrap, pathlib
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compress import GradCompressor

        mesh = jax.make_mesh((4,), ("data",))
        comp = GradCompressor(mode="bf16")
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(0, 1e-2, (4, 1024)), jnp.float32)

        def sync(g_shard):
            state = comp.init_state({"g": g_shard})
            c, _ = comp.compress({"g": g_shard}, state)
            d = comp.decompress(c)["g"]
            return jax.lax.psum(d, "data")

        try:
            from jax import shard_map as sm
            f = sm(sync, mesh=mesh, in_specs=P("data", None),
                   out_specs=P(), check_vma=False)
        except (ImportError, TypeError):
            from jax.experimental.shard_map import shard_map as sm
            f = sm(sync, mesh=mesh, in_specs=P("data", None),
                   out_specs=P(), check_rep=False)
        with mesh:
            out = jax.jit(f)(g)
        ref = jnp.sum(g, axis=0)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 5e-4, err
        print("PSUM_OK")
    """)
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        # JAX_PLATFORMS must survive the env replacement: without it jax
        # probes for accelerator plugins in the child and can hang forever.
        timeout=300, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS",
                                                          "cpu")},
        cwd=str(pathlib.Path(__file__).parent.parent))
    assert "PSUM_OK" in res.stdout, res.stdout + res.stderr


def test_train_step_with_compression_converges():
    """Compressed-gradient training matches uncompressed loss trajectory."""
    from repro.configs import get_config
    from repro.models import build_model, reduce_for_smoke
    from repro.train.train_step import init_train_state, make_train_step
    cfg = dataclasses.replace(reduce_for_smoke(get_config("smollm-360m")),
                              remat=False)
    model = build_model(cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                     cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                      cfg.vocab_size),
    }
    losses = {}
    for mode in ("none", "bf16", "int8"):
        comp = None if mode == "none" else GradCompressor(mode=mode)
        state = init_train_state(model, jax.random.PRNGKey(0),
                                 compressor=comp)
        step = jax.jit(make_train_step(model, compressor=comp))
        for _ in range(8):
            state, metrics = step(state, batch)
        losses[mode] = float(metrics["loss"])
    # compression must not derail optimization
    assert losses["bf16"] < losses["none"] + 0.05
    assert losses["int8"] < losses["none"] + 0.10
    assert losses["none"] < 5.6          # actually learning the batch
