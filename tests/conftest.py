"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override belongs to launch/dryrun.py only)."""
import jax
import numpy as np
import pytest

from repro.core import generate_cluster


@pytest.fixture(scope="session")
def cluster300():
    return generate_cluster(num_apps=300, seed=0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
