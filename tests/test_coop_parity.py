"""Parity suite for the PR-5 pluggable-hierarchy refactor.

The protocol-based default region+host stack must reproduce the
pre-refactor ``cooperate`` outputs bit-for-bit: ``tests/data/coop_golden.json``
was captured at the pre-refactor commit (seed 3, local engine, timeout 4,
8 feedback rounds) and pins the assignment hash, objective, rounds, and
rejection counts at N in {64, 1000} for all three variants plus the
premask-off / restart / cost-budget knob paths.

Also covered here: a no-op custom level appended to the stack never
changes results (property test over seeded clusters), the PR-6 fault
machinery is invisible when idle — a healthy ``BreakerBoard`` and a fresh
``TelemetryMonitor`` leave results bit-identical to the goldens — and the
PR-10 measured-latency level is inert without a sketch bank: swapping
``netlat`` in for ``region`` reproduces the goldens bit-for-bit.
"""

import dataclasses
import hashlib
import json
import os

import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.core import CoopConfig, Hierarchy, Sptlb, generate_cluster
from repro.core.levels import SchedulerLevel
from repro.core.planner import move_costs

with open(os.path.join(os.path.dirname(__file__), "data", "coop_golden.json")) as f:
    GOLDEN = json.load(f)

# name -> (num_apps, CoopConfig kwargs); mirrors the capture script.
CASES = {
    "N64/no_cnst": (64, {"variant": "no_cnst"}),
    "N64/w_cnst": (64, {"variant": "w_cnst"}),
    "N64/manual_cnst": (64, {}),
    "N1000/no_cnst": (1000, {"variant": "no_cnst"}),
    "N1000/w_cnst": (1000, {"variant": "w_cnst"}),
    "N1000/manual_cnst": (1000, {}),
    "N64/manual_cnst/unmasked": (64, {"premask": False}),
    "N64/manual_cnst/restarts": (64, {"restart_rounds": 2}),
    "N64/manual_cnst/budget": (64, {"cost_budget": 3.0, "move_cost": "derive"}),
    "N1000/manual_cnst/unmasked": (1000, {"premask": False}),
}


def _decide(cluster, config):
    return Sptlb(cluster).balance("local", timeout_s=4, config=config)


def _record(cluster, decision, region_level="region"):
    x = np.asarray(decision.assignment, np.int64)
    rec = {
        "assignment_sha": hashlib.sha256(x.tobytes()).hexdigest(),
        "objective": float(decision.solve.objective),
        "num_moved": int(np.sum(x != np.asarray(cluster.problem.assignment0))),
        "d2b": float(decision.difference_to_balance),
    }
    if decision.cooperation is not None:
        tm = decision.cooperation.timings
        rec.update(
            rounds=int(tm["rounds"]),
            feedback_rounds=int(decision.cooperation.feedback_rounds),
            num_rejections=int(decision.cooperation.num_rejections),
            region_rejections=int(tm[f"{region_level}_rejections"]),
            host_rejections=int(tm["host_rejections"]),
            accepted=bool(decision.cooperation.accepted),
            movement_cost=float(tm.get("movement_cost", 0.0)),
            budget_trimmed=int(tm.get("budget_trimmed", 0)),
        )
    return rec


@pytest.mark.parametrize("name", sorted(CASES))
def test_default_stack_matches_pre_refactor_golden(name):
    num_apps, kw = CASES[name]
    cluster = generate_cluster(num_apps=num_apps, seed=3)
    kw = dict(kw)
    if kw.get("move_cost") == "derive":
        kw["move_cost"] = move_costs(cluster.problem)
    got = _record(cluster, _decide(cluster, CoopConfig(max_rounds=8, **kw)))
    want = GOLDEN[name]
    assert got == want, {k: (want[k], got[k]) for k in want if got[k] != want[k]}


def test_explicit_hierarchy_matches_default():
    """Hierarchy.default() / from_names('region,host') are the same stack."""
    cluster = generate_cluster(num_apps=200, seed=3)
    base = _record(cluster, _decide(cluster, CoopConfig()))
    for hierarchy in (Hierarchy.default(), Hierarchy.from_names("region,host")):
        d = Sptlb(cluster).balance("local", timeout_s=4, config=CoopConfig(), hierarchy=hierarchy)
        assert _record(cluster, d) == base


def test_legacy_kwarg_shims_are_gone():
    """PR-5 said the shims last one release; PR-6 is that release."""
    cluster = generate_cluster(num_apps=64, seed=5)
    with pytest.raises(TypeError):
        Sptlb(cluster).balance("local", timeout_s=4, variant="no_cnst")
    with pytest.raises(TypeError):
        Sptlb(cluster).balance("local", timeout_s=4, max_feedback_rounds=4)


@pytest.mark.parametrize("name", ["N64/manual_cnst", "N64/manual_cnst/budget",
                                  "N1000/manual_cnst"])
def test_healthy_breaker_board_matches_golden(name):
    """A BreakerBoard with every breaker closed changes nothing: same
    assignment hash / objective / rounds / rejections as the PR-5 goldens,
    with the board's (all-closed) snapshot surfaced in the timings."""
    from repro.core.health import BreakerBoard

    num_apps, kw = CASES[name]
    cluster = generate_cluster(num_apps=num_apps, seed=3)
    kw = dict(kw)
    if kw.get("move_cost") == "derive":
        kw["move_cost"] = move_costs(cluster.problem)
    board = BreakerBoard()
    d = _decide(cluster, CoopConfig(max_rounds=8, breakers=board, **kw))
    got = _record(cluster, d)
    assert got == GOLDEN[name], {
        k: (GOLDEN[name][k], got[k]) for k in GOLDEN[name]
        if got[k] != GOLDEN[name][k]}
    snap = d.cooperation.timings.breakers
    assert snap["bypassed"] == [] and snap["trips"] == 0
    assert all(b["state"] == "closed" for b in snap["levels"].values())


def test_fresh_telemetry_monitor_is_identity():
    """Fresh, plausible telemetry passes through the monitor unchanged —
    the same ClusterState object, so downstream decisions are untouched."""
    from repro.core.health import TelemetryMonitor

    cluster = generate_cluster(num_apps=150, seed=5)
    monitor = TelemetryMonitor()
    for now in range(3):
        sanitized, health = monitor.ingest(cluster, now, collected_at=now)
        assert sanitized is cluster
        assert health.score == 1.0
        assert health.quarantined == 0


class NoopLevel(SchedulerLevel):
    """A level that accepts everything and constrains nothing."""

    name = "noop"

    def __init__(self, cluster):
        self.cluster = cluster


@hypothesis.settings(max_examples=5, deadline=None)
@hypothesis.given(
    st.sampled_from([64, 150, 300]),
    st.integers(0, 5),
    st.sampled_from([True, False]),
)
def test_noop_custom_level_never_changes_results(num_apps, seed, premask):
    """Appending a no-op level anywhere in the stack is invisible: same
    assignment, objective, rounds, and rejection counts as the default."""
    cluster = generate_cluster(num_apps=num_apps, seed=seed)
    cfg = CoopConfig(premask=premask)
    base = _record(cluster, _decide(cluster, cfg))
    stacked = Hierarchy(
        (
            lambda c: NoopLevel(c),
            *Hierarchy.default().factories,
            lambda c: NoopLevel(c),
        )
    )
    d = Sptlb(cluster).balance("local", timeout_s=4, config=cfg, hierarchy=stacked)
    got = _record(cluster, d)
    assert got == base
    # the no-op level is visible in the observability, invisible in results
    tm = d.cooperation.timings
    assert tm["noop_rejections"] == 0
    assert "noop" in tm.levels


@pytest.mark.parametrize(
    "name,premask",
    [
        ("N64/manual_cnst", {"region": True, "host": True}),
        ("N64/manual_cnst", {}),  # absent levels default to True
        ("N64/manual_cnst/unmasked", {"region": False, "host": False}),
    ],
)
def test_premask_mapping_matches_bool_golden(name, premask):
    """The PR-7 per-level premask mapping is a strict generalization of the
    historical bool: all-True (and empty, via the default) reproduces the
    masked golden bit-for-bit, all-False the unmasked one."""
    cluster = generate_cluster(num_apps=64, seed=3)
    got = _record(
        cluster, _decide(cluster, CoopConfig(max_rounds=8, premask=premask))
    )
    want = GOLDEN[name]
    assert got == want, {k: (want[k], got[k]) for k in want if got[k] != want[k]}


def test_inactive_shed_plan_is_bit_identical():
    """The overload throttle off is really off: ``shed=None`` and an
    inactive plan (caps all ones) both reproduce the golden exactly."""
    from repro.core.shedding import ShedPlan

    cluster = generate_cluster(num_apps=64, seed=3)
    inert = ShedPlan(caps=np.ones(cluster.problem.num_apps, np.float32))
    for shed in (None, inert):
        got = _record(
            cluster, _decide(cluster, CoopConfig(max_rounds=8, shed=shed))
        )
        assert got == GOLDEN["N64/manual_cnst"], shed


def test_inert_netlat_level_is_the_static_region_contract():
    """PR 10's measured-latency level with no bank installed degrades to
    exactly the static region contract: swapping region -> netlat in the
    stack reproduces the PR-5 goldens bit-for-bit (the level reports its
    rejections under its own name), and merely importing the package —
    which registers the level — perturbs nothing."""
    import repro.netlat as netlat

    netlat.install_bank(None)  # explicit: no measurement state bound
    for name in ("N64/manual_cnst", "N1000/manual_cnst"):
        num_apps, kw = CASES[name]
        cluster = generate_cluster(num_apps=num_apps, seed=3)
        cfg = CoopConfig(max_rounds=8, levels=("netlat", "host"), **kw)
        got = _record(cluster, _decide(cluster, cfg), region_level="netlat")
        want = GOLDEN[name]
        assert got == want, {k: (want[k], got[k]) for k in want if got[k] != want[k]}
    # Registration alone is side-effect free: the default region+host
    # stack still matches its golden with the netlat package imported.
    cluster = generate_cluster(num_apps=64, seed=3)
    got = _record(cluster, _decide(cluster, CoopConfig(max_rounds=8)))
    assert got == GOLDEN["N64/manual_cnst"]


def test_controller_config_legacy_fields_fold_into_coop():
    from repro.core.controller import ControllerConfig

    cfg = ControllerConfig(variant="no_cnst", restart_rounds=3)
    assert cfg.coop.variant == "no_cnst"
    assert cfg.coop.restart_rounds == 3
    explicit = ControllerConfig(coop=CoopConfig(levels=("region", "host", "shard")))
    assert explicit.coop.levels == ("region", "host", "shard")
    carried = dataclasses.replace(explicit, movement_cost_budget=5.0)
    assert carried.coop.levels == ("region", "host", "shard")


def test_plan_relax_path_unchanged_through_levels():
    """Maintenance placement mode now flows through the level relax hooks;
    the resulting per-app region budget must match the historical
    ``np.where(relax_home_tiers[x0], base * factor, base)`` array."""
    from repro.core.hierarchy import REGION_LATENCY_BUDGET_MS, RegionScheduler
    from repro.core.planner import PlanOutlook

    cluster = generate_cluster(num_apps=120, seed=2)
    T = cluster.problem.num_tiers
    relax = np.zeros(T, bool)
    relax[2] = True
    plan = PlanOutlook(
        now=0,
        horizon=8,
        tier_factor=np.ones(T, np.float32),
        avoid_tiers=np.zeros(T, bool),
        slo_off_tiers=np.zeros(T, bool),
        pending=1,
        relax_home_tiers=relax,
        relax_latency_factor=1.5,
    )
    level = RegionScheduler(cluster)
    level.relax(plan, cluster)
    x0 = np.asarray(cluster.problem.assignment0)
    want = np.where(
        relax[x0], REGION_LATENCY_BUDGET_MS * 1.5, REGION_LATENCY_BUDGET_MS
    ).astype(np.float32)
    assert level.budget is None
    np.testing.assert_array_equal(level._budget_per_app, want)
