"""Paper Fig. 3 (a, b, c): SPTLB vs per-objective greedy schedulers.

Reproduces the claim: SPTLB balances cpu, mem AND task count in one mapping;
each greedy variant balances only its own objective and leaves the others
unbalanced (sometimes past the ideal limit).

Output: per-tier utilization tables (initial / SPTLB / greedy-{cpu,mem,task})
for each objective + the spread summary + claim checks.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import comment, emit, load_cluster
from repro.core import (GreedyConfig, LocalSearchConfig, solve_greedy,
                        solve_local, utilization_fraction, validate)


def run(num_apps: int = 1200, timeout_s: int = 30):
    cluster = load_cluster(num_apps)
    p = cluster.problem
    from repro.core.sptlb import TIMEOUT_BUDGETS
    budget = TIMEOUT_BUDGETS[timeout_s]

    results = {}
    import time
    t0 = time.perf_counter()
    res = solve_local(p, LocalSearchConfig(max_iters=budget))
    results["sptlb"] = (res, time.perf_counter() - t0)
    for obj in ("cpu", "mem", "task"):
        t0 = time.perf_counter()
        g = solve_greedy(p, GreedyConfig(objective=obj, max_steps=budget))
        results[f"greedy-{obj}"] = (g, time.perf_counter() - t0)

    uf0, tf0 = utilization_fraction(p, p.assignment0)
    uf0, tf0 = np.asarray(uf0), np.asarray(tf0)

    tables = {"cpu": {}, "mem": {}, "task": {}}
    spreads = {}
    for name, (res, dt) in results.items():
        uf, tf = utilization_fraction(p, res.assignment)
        uf, tf = np.asarray(uf), np.asarray(tf)
        tables["cpu"][name] = uf[:, 0]
        tables["mem"][name] = uf[:, 1]
        tables["task"][name] = tf
        spreads[name] = {
            "cpu": float(uf[:, 0].max() - uf[:, 0].min()),
            "mem": float(uf[:, 1].max() - uf[:, 1].min()),
            "task": float(tf.max() - tf.min()),
        }
        emit(f"fig3/{name}", dt * 1e6,
             f"spread_cpu={spreads[name]['cpu']:.3f};"
             f"spread_mem={spreads[name]['mem']:.3f};"
             f"spread_task={spreads[name]['task']:.3f};"
             f"moved={res.num_moved};feasible={validate(p, res.assignment).ok}")

    initial = {"cpu": uf0[:, 0], "mem": uf0[:, 1], "task": tf0}
    for objective in ("cpu", "mem", "task"):
        ideal = 0.8 if objective == "task" else 0.7
        comment(f"--- Fig 3 ({objective}): per-tier utilization fraction "
                f"(ideal {ideal:.0%}) ---")
        header = "tier     initial  " + "  ".join(
            f"{n:>12s}" for n in results)
        comment(header)
        for t in range(p.num_tiers):
            row = f"tier_{t+1}   {initial[objective][t]:6.2f}  " + "  ".join(
                f"{tables[objective][n][t]:12.2f}" for n in results)
            comment(row)

    # --- paper-claim checks ---
    claims = []
    s = spreads
    claims.append(("sptlb balances all three objectives",
                   all(s["sptlb"][o] < max(0.5 * (initial[o].max()
                                                  - initial[o].min()), 0.12)
                       for o in ("cpu", "mem", "task"))))
    for obj in ("cpu", "mem", "task"):
        others = [o for o in ("cpu", "mem", "task") if o != obj]
        claims.append((
            f"greedy-{obj} balances {obj} but leaves another objective "
            f">=1.5x worse than sptlb",
            s[f"greedy-{obj}"][obj] < 0.6 * (initial[obj].max()
                                             - initial[obj].min())
            and any(s[f"greedy-{obj}"][o] > 1.5 * s["sptlb"][o]
                    for o in others)))
    for text, ok in claims:
        comment(f"CLAIM [{'PASS' if ok else 'FAIL'}]: {text}")
    return spreads, claims


if __name__ == "__main__":
    run()
