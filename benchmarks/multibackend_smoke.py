"""Multi-backend smoke: the vmapped sharded solve and ``move_eval_best``
under an explicit ``set_platform`` per backend (PR 8 satellite).

``jax_platform_name`` only takes effect at program start (the
``set_platform`` idiom), so one process cannot test CPU then GPU.  The
parent enumerates the platforms actually present, then re-execs itself
(``--platform X``) once per backend; each child pins the platform BEFORE
importing ``repro`` and runs the two surfaces CI must cover off-TPU:

  * ``ops.move_eval_best`` (the solver's fused hot kernel, XLA path),
  * a small batched shard solve (partition -> vmap -> merge) with its
    zero-stranded merge invariant.

CPU always runs; GPU runs when a device is visible.  TPU is exercised by
the launch tooling, not this smoke.  Run what CI runs:

    PYTHONPATH=src python -m benchmarks.multibackend_smoke
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys


def set_platform(platform: str) -> None:
    """Pin the JAX backend.  Only effective at program start, so the caller
    must not have imported anything that touched a device yet."""
    import jax

    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        # https://jax.readthedocs.io/en/latest/gpu_performance_tips.html
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_gpu_triton_gemm_any=True"
            + " --xla_gpu_enable_latency_hiding_scheduler=true"
        )


def child(platform: str) -> None:
    """Runs in a fresh process with the platform pinned pre-import."""
    set_platform(platform)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import comment, random_problem_arrays
    from repro.kernels import ops
    from repro.shard import FleetConfig, solve_fleet, synthetic_fleet

    devices = jax.devices()
    assert devices[0].platform == platform, (devices, platform)
    comment(f"[{platform}] {len(devices)} device(s): {devices[0].device_kind}")

    # 1. the solver's fused hot kernel
    N, T = 1_024, 16
    args = random_problem_arrays(N, T, seed=3)
    feas = jnp.ones((N, T), bool)
    score, tier = ops.move_eval_best(*args, feas, jnp.int32(5), impl="xla")
    score, tier = np.asarray(score), np.asarray(tier)
    finite = np.isfinite(score)
    assert finite.any(), "move_eval_best produced no finite scores"
    assert ((tier[finite] >= 0) & (tier[finite] < T)).all()
    comment(f"[{platform}] move_eval_best ok: {int(finite.sum())}/{N} finite")

    # 2. the batched (vmapped) shard solve, end to end
    cluster = synthetic_fleet(2_000, num_tiers=16, seed=5)
    fd = solve_fleet(cluster, FleetConfig(num_shards=4, timeout_s=30))
    assert fd.stranded == 0, f"{fd.stranded} stranded apps after merge"
    assert bool(fd.solve.converged.all()) or int(fd.solve.iterations.max()) > 0
    comment(f"[{platform}] sharded solve ok: objective {fd.objective:.4g}, "
            f"{fd.apps_per_s:.3e} apps/s")
    print(f"MULTIBACKEND_OK {platform}")


def available_platforms() -> list:
    """CPU always; GPU when jax can actually see one (probed in a child so
    the probe's backend init cannot leak into ours)."""
    platforms = ["cpu"]
    probe = subprocess.run(
        [sys.executable, "-c",
         "import jax; print(len(jax.devices('gpu')))"],
        capture_output=True, text=True, env=os.environ.copy())
    if probe.returncode == 0 and probe.stdout.strip().isdigit() \
            and int(probe.stdout.strip()) > 0:
        platforms.append("gpu")
    return platforms


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--platform", default=None,
                    help="internal: run the smoke on this backend")
    args = ap.parse_args()
    if args.platform:
        child(args.platform)
        return 0

    failures = 0
    for platform in available_platforms():
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.multibackend_smoke",
             "--platform", platform],
            env=os.environ.copy())
        if proc.returncode != 0:
            print(f"MULTIBACKEND_FAIL {platform}")
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
