"""Solver hot-spot scaling: move_eval throughput, batched-vs-single-move
LocalSearch iteration rate, cooperation-round phase split, and jit-cache
behaviour under drifting app counts (the paper's "TBs per second" scale
argument applied to the scheduler itself).

Emits CSV rows like every other benchmark AND writes ``BENCH_solver.json``
at the repo root so the solver-throughput trajectory is tracked PR-over-PR:
  * local_search: committed moves/sec for batch_moves=1 vs 16 (the PR 1
    acceptance number: >=5x at N=10_000),
  * cooperate: manual_cnst pass with region pre-masking off vs on —
    per-phase split (solve / pack / region / host glue / feedback), rounds,
    region+host rejection breakdown, pack dispatch/retrace counters (the
    PR 2 acceptance numbers: host_side_frac <= 0.10 and >=1.5x total
    speedup at N=10_000 with premask on, at 0 region rejections and an
    objective no worse than the unmasked path),
  * bucketing: LocalSearch retrace counts across drifting app counts with
    shape-bucketed padding on vs off.

Also benches the Pallas kernels in interpret mode for *correct-path* parity;
interpret-mode timing is NOT a TPU number (the roofline for the kernel is
derived in EXPERIMENTS.md §Roofline from its arithmetic intensity instead).

``--smoke`` shrinks every size so CI can run the whole file in seconds.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import comment, emit, random_problem_arrays
from repro.core import (CoopConfig, LocalSearchConfig, Sptlb,
                        generate_cluster, solve_local)
from repro.core.sptlb import engine_fn
from repro.core.solver_local import local_search_trace_count
from repro.kernels import ops
from repro.shard import FleetConfig, solve_fleet, synthetic_fleet

RESULTS: dict = {}


def bench_move_eval(N: int, T: int, reps: int = 5):
    args = random_problem_arrays(N, T, seed=0)
    fn = jax.jit(lambda *a: ops.move_eval(*a, impl="xla"))
    fn(*args).block_until_ready()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        times.append(time.perf_counter() - t0)
    us = float(np.median(times)) * 1e6
    candidates_per_s = N * T / (us / 1e6)
    emit(f"solver_scale/move_eval/N{N}xT{T}", us,
         f"candidates_per_s={candidates_per_s:.3e}")
    RESULTS.setdefault("move_eval", {})[f"N{N}xT{T}"] = {
        "us_per_sweep": us, "candidates_per_s": candidates_per_s}
    return us


def bench_local_search_batched(N: int, sweeps: int = 64, batch: int = 16):
    """Committed-moves/sec of the top-k batched path vs the single-move path.

    Both variants run the same candidate-sweep budget; rate is measured on a
    second (jit-warm) run.
    """
    cluster = generate_cluster(num_apps=N, seed=1)
    p = cluster.problem
    rates = {}
    for bm in (1, batch):
        cfg = LocalSearchConfig(max_iters=sweeps, batch_moves=bm)
        solve_local(p, cfg)                                  # compile + warm
        t0 = time.perf_counter()
        res = solve_local(p, cfg)
        dt = time.perf_counter() - t0
        committed = res.extra["committed_moves"]
        rate = committed / dt if dt > 0 else float("inf")
        rates[bm] = rate
        emit(f"solver_scale/local_search/N{N}/batch{bm}", dt * 1e6,
             f"sweeps={res.extra['sweeps']};committed={committed};"
             f"moves_per_s={rate:.1f};objective={res.objective:.4g}")
        RESULTS.setdefault("local_search", {}).setdefault(f"N{N}", {})[
            f"batch{bm}"] = {
                "seconds": dt, "sweeps": res.extra["sweeps"],
                "committed_moves": committed, "moves_per_s": rate,
                "objective": res.objective}
    speedup = rates[batch] / rates[1] if rates[1] > 0 else float("inf")
    comment(f"N={N}: batched committed-move rate speedup = {speedup:.1f}x")
    RESULTS["local_search"][f"N{N}"]["speedup"] = speedup
    return speedup


def bench_cooperate(N: int, timeout_s: int = 8):
    """Cooperation section (PR 2 tentpole + PR 5 bus): per-phase split,
    rounds, per-level rejection breakdown, and pack dispatch/retrace
    counters of a manual_cnst pass with level pre-masking off vs on, all
    through the generic cooperation bus (``CoopConfig`` + default
    region+host ``Hierarchy``).  host_side_frac is everything that is
    neither the solver nor the levels' compiled dispatches (acceptance:
    <=0.10 at N=10_000 with premask on); bus_overhead_frac isolates the
    generic bus's own routing glue (wall-clock belonging to no phase),
    gated <= ~5% so the protocol refactor can never quietly tax the
    two-level hot path.  A third record runs the region+host+shard stack —
    the plugin-level cost is observable, not gated."""
    cluster = generate_cluster(num_apps=N, seed=2)
    s = Sptlb(cluster)
    rec = {}
    cases = {
        "unmasked": CoopConfig(premask=False),
        "premask": CoopConfig(premask=True),
        "shard_stack": CoopConfig(premask=True,
                                  levels=("region", "host", "shard")),
    }
    for label, cfg in cases.items():
        s.balance("local", timeout_s=timeout_s, config=cfg)      # warm jit
        d = s.balance("local", timeout_s=timeout_s, config=cfg)
        tm = dict(d.cooperation.timings)
        rec[label] = {**tm, "objective": d.solve.objective,
                      "d2b": d.difference_to_balance,
                      "accepted": d.cooperation.accepted}
        shard_rej = tm.get("shard_rejections", "-")
        emit(f"solver_scale/cooperate/N{N}/{label}", tm["total_s"] * 1e6,
             f"rounds={tm['rounds']};region_rej={tm['region_rejections']};"
             f"host_rej={tm['host_rejections']};shard_rej={shard_rej};"
             f"solve_s={tm['solve_s']:.3f};"
             f"pack_s={tm['pack_s']:.4f};"
             f"pack_dispatches={tm['pack_dispatches']};"
             f"pack_retraces={tm['pack_retraces']};"
             f"host_side_frac={tm['host_side_frac']:.3f};"
             f"bus_overhead_frac={tm['bus_overhead_frac']:.3f};"
             f"objective={d.solve.objective:.4g}")
    rec["speedup_premask"] = (rec["unmasked"]["total_s"]
                              / max(rec["premask"]["total_s"], 1e-12))
    comment(f"N={N}: premask {rec['speedup_premask']:.2f}x faster, "
            f"rounds {rec['unmasked']['rounds']} -> {rec['premask']['rounds']}, "
            f"region rejections {rec['unmasked']['region_rejections']} -> "
            f"{rec['premask']['region_rejections']}, host_side_frac "
            f"{rec['premask']['host_side_frac']:.3f}, bus_overhead_frac "
            f"{rec['premask']['bus_overhead_frac']:.3f}, 3-level stack "
            f"{rec['shard_stack']['total_s']:.3f}s")
    RESULTS.setdefault("cooperate", {})[f"N{N}"] = rec
    return rec


def bench_bucketing(sizes: tuple, timeout_s: int = 4):
    """LocalSearch retrace counts across drifting app counts."""
    counts = {}
    for bucketed in (True, False):
        total = 0
        for i, N in enumerate(sizes):
            cluster = generate_cluster(num_apps=N, seed=10 + i)
            fn = engine_fn("local", timeout_s, bucket_apps=bucketed)
            before = local_search_trace_count()
            fn(cluster.problem)
            total += local_search_trace_count() - before
        counts["bucketed" if bucketed else "unbucketed"] = total
    emit(f"solver_scale/bucketing/{'x'.join(map(str, sizes))}", 0.0,
         f"retraces_bucketed={counts['bucketed']};"
         f"retraces_unbucketed={counts['unbucketed']}")
    RESULTS["bucketing"] = {"sizes": list(sizes), **counts}
    return counts


def bench_shard_scale(cases, timeout_s: int = 30):
    """Sharded fleet pass (PR 8): apps/sec and rebalance-pass wall-clock vs
    shard count, on the vectorized synthetic fleet (generate_cluster's
    Python loops do not reach 100k+ apps).  One warm pass compiles the
    (S, Nb, Tb) executable; the measured pass is jit-warm, so the tracked
    number is steady-state rebalance latency, not compile time.  The hard
    invariant tracked alongside throughput: zero apps stranded after the
    partition -> solve -> merge -> coordinate pass."""
    clusters: dict = {}
    for N, T, S in cases:
        if (N, T) not in clusters:
            t0 = time.perf_counter()
            clusters[(N, T)] = synthetic_fleet(N, num_tiers=T, seed=9)
            comment(f"synthetic_fleet N={N} T={T} built in "
                    f"{time.perf_counter() - t0:.1f}s")
        cluster = clusters[(N, T)]
        cfg = FleetConfig(num_shards=S, timeout_s=timeout_s)
        solve_fleet(cluster, cfg)                            # compile + warm
        fd = solve_fleet(cluster, cfg)
        key = f"N{N}_S{S}"
        emit(f"solver_scale/shard_scale/{key}", fd.timings["total_s"] * 1e6,
             f"apps_per_s={fd.apps_per_s:.3e};stranded={fd.stranded};"
             f"migrations={fd.migrations};saturated={fd.saturated};"
             f"coord_frac={fd.coordinator_overhead_frac:.4f};"
             f"solve_s={fd.timings['solve_s']:.3f};"
             f"objective={fd.objective:.4g}")
        RESULTS.setdefault("shard_scale", {})[key] = {
            "apps": N, "tiers": T, "num_shards": S,
            "app_bucket": fd.sharded.app_bucket,
            "tier_bucket": fd.sharded.tier_bucket,
            "apps_per_s": fd.apps_per_s,
            "stranded": fd.stranded, "migrations": fd.migrations,
            "saturated": fd.saturated,
            "coordinator_overhead_frac": fd.coordinator_overhead_frac,
            "objective": fd.objective, **fd.timings}
    recs = RESULTS.get("shard_scale", {})
    if recs:
        best = max(recs.values(), key=lambda r: r["apps_per_s"])
        comment(f"shard_scale: best apps/sec {best['apps_per_s']:.3e} at "
                f"N={best['apps']} S={best['num_shards']}")


def bench_pallas_parity(N: int, T: int):
    t0 = time.perf_counter()
    comment("pallas interpret-mode parity check (runs the kernel bodies)")
    args = random_problem_arrays(N, T, seed=7)
    d_ref = ops.move_eval(*args, impl="xla")
    d_pal = ops.move_eval(*args, impl="pallas")
    err = float(jnp.max(jnp.abs(d_ref - d_pal))
                / (jnp.max(jnp.abs(d_ref)) + 1e-9))
    emit("solver_scale/move_eval_pallas_parity",
         (time.perf_counter() - t0) * 1e6, f"rel_err={err:.2e}")
    t0 = time.perf_counter()
    feas = jnp.ones((N, T), bool)
    s_ref, t_ref = ops.move_eval_best(*args, feas, jnp.int32(5), impl="xla")
    s_pal, t_pal = ops.move_eval_best(*args, feas, jnp.int32(5), impl="pallas")
    finite = np.isfinite(np.asarray(s_ref))
    scale = float(jnp.max(jnp.abs(jnp.where(finite, s_ref, 0.0)))) + 1e-9
    err = float(np.max(np.abs((np.asarray(s_pal) - np.asarray(s_ref))[finite]))
                / scale)
    tier_agree = float(np.mean(np.asarray(t_pal)[finite]
                               == np.asarray(t_ref)[finite]))
    emit("solver_scale/move_eval_best_pallas_parity",
         (time.perf_counter() - t0) * 1e6,
         f"rel_err={err:.2e};tier_agreement={tier_agree:.3f}")
    RESULTS["pallas_parity"] = {"rel_err": err, "tier_agreement": tier_agree}


def run(smoke: bool = False):
    comment(f"--- solver hot-spot scaling (XLA path, CPU{', smoke' if smoke else ''}) ---")
    if smoke:
        for N, T in ((1_000, 5), (2_000, 16)):
            bench_move_eval(N, T)
        bench_local_search_batched(500, sweeps=16)
        bench_cooperate(400, timeout_s=4)
        bench_bucketing((300, 320, 350), timeout_s=4)
        bench_shard_scale(((2_000, 16, 1), (2_000, 16, 4)))
        bench_pallas_parity(512, 16)
    else:
        for N, T in ((1_000, 5), (10_000, 16), (100_000, 64), (100_000, 128)):
            bench_move_eval(N, T)
        for N in (1_000, 3_000):
            bench_local_search_batched(N, sweeps=32)
        bench_local_search_batched(10_000, sweeps=64)   # the acceptance number
        bench_cooperate(10_000, timeout_s=8)
        bench_bucketing((3_000, 3_100, 3_250), timeout_s=4)
        bench_shard_scale(((100_000, 64, 4), (100_000, 64, 16),
                           (1_000_000, 64, 8), (1_000_000, 64, 32)))
        bench_pallas_parity(4_096, 128)

    # Smoke numbers must not clobber the tracked fleet-scale record.
    name = "BENCH_solver_smoke.json" if smoke else "BENCH_solver.json"
    out_path = os.path.normpath(os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", name))
    with open(out_path, "w") as f:
        json.dump(RESULTS, f, indent=2, sort_keys=True)
    comment(f"wrote {out_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (seconds, not minutes)")
    run(**vars(ap.parse_args()))
