"""Solver hot-spot scaling: move_eval throughput + LocalSearch iteration rate
vs problem size (the paper's "TBs per second" scale argument applied to the
scheduler itself).

Also benches the Pallas kernel in interpret mode for *correct-path* parity;
interpret-mode timing is NOT a TPU number (the roofline for the kernel is
derived in EXPERIMENTS.md §Roofline from its arithmetic intensity instead).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import comment, emit
from repro.core import LocalSearchConfig, generate_cluster, solve_local
from repro.kernels import ops


def bench_move_eval(N: int, T: int, reps: int = 5):
    rng = np.random.default_rng(0)
    demand = jnp.asarray(rng.lognormal(1, 0.8, (N, 2)), jnp.float32)
    tasks = jnp.asarray(rng.integers(1, 40, N), jnp.float32)
    crit = jnp.asarray(rng.random(N), jnp.float32)
    x = jnp.asarray(rng.integers(0, T, N), jnp.int32)
    x0 = jnp.asarray(rng.integers(0, T, N), jnp.int32)
    cap = jnp.asarray(rng.uniform(400, 900, (T, 2)), jnp.float32)
    klim = jnp.asarray(rng.uniform(800, 2000, T), jnp.float32)
    ideal = jnp.full((T, 2), 0.7, jnp.float32)
    ideal_t = jnp.full((T,), 0.8, jnp.float32)
    util = jax.ops.segment_sum(demand, x, num_segments=T)
    tt = jax.ops.segment_sum(tasks, x, num_segments=T)
    w = jnp.asarray([1e4, 1e3, 1e2, 1e1, 1e0], jnp.float32)
    args = (demand, tasks, crit, x, x0, cap, klim, ideal, ideal_t, util, tt, w)

    fn = jax.jit(lambda *a: ops.move_eval(*a, impl="xla"))
    fn(*args).block_until_ready()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        times.append(time.perf_counter() - t0)
    us = float(np.median(times)) * 1e6
    candidates_per_s = N * T / (us / 1e6)
    emit(f"solver_scale/move_eval/N{N}xT{T}", us,
         f"candidates_per_s={candidates_per_s:.3e}")
    return us


def bench_local_search(N: int, iters: int = 64):
    cluster = generate_cluster(num_apps=N, seed=1)
    p = cluster.problem
    solve_local(p, LocalSearchConfig(max_iters=4))        # compile
    t0 = time.perf_counter()
    res = solve_local(p, LocalSearchConfig(max_iters=iters))
    dt = time.perf_counter() - t0
    emit(f"solver_scale/local_search/N{N}", dt * 1e6,
         f"iters={res.iterations};iters_per_s={res.iterations / dt:.1f};"
         f"moved={res.num_moved}")
    return dt


def run():
    comment("--- solver hot-spot scaling (XLA path, CPU) ---")
    for N, T in ((1_000, 5), (10_000, 16), (100_000, 64), (100_000, 128)):
        bench_move_eval(N, T)
    for N in (300, 1_000, 3_000, 10_000):
        bench_local_search(N)
    # Pallas interpret-mode parity (not a perf number on CPU)
    rngN, rngT = 4_096, 128
    t0 = time.perf_counter()
    comment("pallas interpret-mode parity check (runs the kernel body)")
    from tests.test_kernels import _random_problem_arrays  # reuse builder
    args = _random_problem_arrays(rngN, rngT, seed=7)
    d_ref = ops.move_eval(*args, impl="xla")
    d_pal = ops.move_eval(*args, impl="pallas")
    err = float(jnp.max(jnp.abs(d_ref - d_pal))
                / (jnp.max(jnp.abs(d_ref)) + 1e-9))
    emit("solver_scale/move_eval_pallas_parity", (time.perf_counter() - t0) * 1e6,
         f"rel_err={err:.2e}")


if __name__ == "__main__":
    run()
