"""Fleet-simulator scenario benchmark: controller vs no-rebalance baseline
over every registry scenario, scored by trajectory-level SLO accounting.

For each scenario the harness runs the same workload trajectory twice —
``static`` (the t=0 placement rides out the run) and ``balanced``
(``BalanceController`` ticks with hysteresis/cooldown, anticipating any
declared maintenance advisories and pricing movement against the
scenario's budget) — and records the violation integrals, priced movement
vs budget, d2b series, and solver wall-clock.  The per-scenario comparison
ratios are the PR 3/4 acceptance numbers (tier_drain must stay <= 0.15
with movement inside the budget; region_outage must not regress), and
``benchmarks/check_regression.py`` gates them in CI.

Chaos scenarios (``Scenario.chaos``) run the degraded/oracle/static triple
via ``run_chaos_pair`` instead: their records carry the ``chaos``
scorecard (unsafe moves, mode residency and transitions, recovery,
degraded-vs-oracle violation ratio) that the regression gate pins — see
docs/degraded_modes.md.

Overload scenarios (``Scenario.overload``) run the binary-baseline vs
utility-armed pair via ``run_overload_pair``: their records carry the
``overload`` scorecard (delivered-utility improvement, admission/shedding
counters, the zero-infeasible-admissions invariant) — see
docs/overload_and_admission.md.

Network scenarios (``Scenario.netlat``) run the static-36ms vs
measured-budget pair via ``run_netlat_pair``: their records carry the
``netlat`` scorecard (p99-aware placement-latency ratio < 1, zero
budget-exceeding committed moves under live measured budgets) — see
docs/latency_slo.md.  ``service_ingest`` additionally drives one
``ServiceLoop`` from N concurrent producer threads (the thread-safe
``submit`` path) and records sustained events/s and re-solve latency.

Emits CSV rows like every other benchmark AND writes ``BENCH_sim.json`` at
the repo root so the trajectory scorecard is tracked PR-over-PR
(regenerate with ``PYTHONPATH=src python -m benchmarks.sim_scenarios``;
``--smoke`` shrinks apps/ticks for CI and writes BENCH_sim_smoke.json).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks.common import comment, emit
from repro.sim import (get_scenario, list_scenarios, run_chaos_pair,
                       run_netlat_pair, run_overload_pair, run_pair)

RESULTS: dict = {}


def bench_netlat_scenario(sc, num_apps: int, ticks: int):
    """Network scenarios run the static-budget/measured-budget pair: the
    record keys the gate pins are the ``netlat`` scorecard (the measured
    stack's p99-aware placement-latency integral at ratio <= 1 vs the
    static 36 ms stack, zero committed moves whose destination exceeds a
    live measured p99 budget, calibration achieved)."""
    t0 = time.perf_counter()
    out = run_netlat_pair(sc)
    wall = time.perf_counter() - t0
    n = out["netlat"]
    rec = {
        "num_apps": num_apps,
        "pool": sc.max_apps,
        "ticks": ticks,
        "wall_s": wall,
        "static": out["static"].summary(),
        "measured": out["measured"].summary(),
        "netlat": n,
        "series": {"static": out["static"].series(),
                   "measured": out["measured"].series()},
    }
    p99 = n["network_p99_integral"]
    bex = n["budget_exceeding_moves"]
    emit(f"sim_scenarios/{sc.name}/N{num_apps}x{ticks}", wall * 1e6,
         f"p99_static={p99['static']:.1f};p99_measured={p99['measured']:.1f};"
         f"p99_ratio={p99['ratio']:.4f};"
         f"bex_static={bex['static']};bex_measured={bex['measured']};"
         f"moves_static={n['moves']['static']};"
         f"moves_measured={n['moves']['measured']};"
         f"calibrated={n['calibrated']};relax={n['relax_factor']:.3f};"
         f"quarantined={n['quarantined_samples']}")
    comment(f"{sc.name} (netlat): p99 integral {p99['static']:.0f} -> "
            f"{p99['measured']:.0f} ({p99['ratio']:.3f}x), budget-exceeding "
            f"moves {bex['static']} -> {bex['measured']}, moves "
            f"{n['moves']['static']} -> {n['moves']['measured']}")
    RESULTS[sc.name] = rec
    return rec


def bench_service_ingest(num_apps: int, ticks: int, producers: int = 4):
    """Multi-producer ingestion: ``producers`` concurrent threads submit
    telemetry deltas for disjoint app partitions while the main thread
    steps the loop — the thread-safe ``submit`` path under contention.
    The gate pins zero dropped events and per-app sequence monotonicity;
    the operational numbers are sustained events/s and re-solve p50/p99."""
    import threading

    import numpy as np

    from repro.core import generate_cluster
    from repro.core.controller import BalanceController, ControllerConfig
    from repro.service import ServiceLoop, TelemetryDelta

    cluster = generate_cluster(num_apps=num_apps, seed=7)
    ctl = BalanceController(cluster, ControllerConfig(timeout_s=30))
    loop = ServiceLoop(controller=ctl)
    dem0 = np.asarray(cluster.problem.demand, np.float32)
    tsk0 = np.asarray(cluster.problem.tasks, np.float32)
    live = np.where(np.asarray(cluster.problem.valid))[0]
    chunks = [c for c in np.array_split(live, producers) if c.size]

    def produce(pid: int, ids: np.ndarray) -> None:
        rng = np.random.default_rng(100 + pid)
        for r in range(ticks):
            skew = rng.uniform(0.9, 1.15, size=(ids.size, 1)).astype(
                np.float32)
            loop.submit(TelemetryDelta(
                app_ids=tuple(int(n) for n in ids),
                demand=dem0[ids] * skew, tasks=tsk0[ids].copy(),
                collected_at=r))

    t0 = time.perf_counter()
    threads = [threading.Thread(target=produce, args=(i, c))
               for i, c in enumerate(chunks)]
    for t in threads:
        t.start()
    step = 0
    while any(t.is_alive() for t in threads) or loop._queue:
        loop.step(step)
        step += 1
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    stats = loop.stats()
    ordered = all(seqs == sorted(seqs)
                  for seqs in loop.shadow.applied_seq.values())
    rec = {
        "num_apps": num_apps,
        "producers": len(chunks),
        "events_per_producer": ticks,
        "wall_s": wall,
        "events_submitted": stats["events_submitted"],
        "dropped_events": stats["dropped_events"],
        "per_app_ordered": ordered,
        "ingest_events_per_s": (stats["events_applied"] / wall
                                if wall > 0 else 0.0),
        "stats": stats,
    }
    emit(f"sim_scenarios/service_ingest/N{num_apps}x{ticks}", wall * 1e6,
         f"producers={len(chunks)};"
         f"events={stats['events_submitted']};"
         f"dropped={stats['dropped_events']};ordered={ordered};"
         f"ingest_events_per_s={rec['ingest_events_per_s']:.0f};"
         f"resolve_p50_ms={stats['resolve_p50_ms']:.1f};"
         f"resolve_p99_ms={stats['resolve_p99_ms']:.1f}")
    comment(f"service_ingest: {len(chunks)} producers x {ticks} deltas, "
            f"{rec['ingest_events_per_s']:.0f} events/s ingested, "
            f"{stats['dropped_events']} dropped, re-solve p50 "
            f"{stats['resolve_p50_ms']:.1f} ms / p99 "
            f"{stats['resolve_p99_ms']:.1f} ms")
    RESULTS["service_ingest"] = rec
    return rec


def bench_overload_scenario(sc, num_apps: int, ticks: int):
    """Overload scenarios run the binary-baseline/utility-armed pair: the
    record keys the gate pins are the ``overload`` scorecard (delivered-
    utility improvement > 1 on the same trajectory and the same curves,
    zero infeasible admissions, bounded shed churn, budgets held)."""
    t0 = time.perf_counter()
    out = run_overload_pair(sc)
    wall = time.perf_counter() - t0
    o = out["overload"]
    rec = {
        "num_apps": num_apps,
        "pool": sc.max_apps,
        "ticks": ticks,
        "wall_s": wall,
        "binary": out["binary"].summary(),
        "utility": out["utility"].summary(),
        "overload": o,
        "series": {"binary": out["binary"].series(),
                   "utility": out["utility"].series()},
    }
    r = o["delivered_utility_ratio"]
    adm = o["admission"]
    emit(f"sim_scenarios/{sc.name}/N{num_apps}x{ticks}", wall * 1e6,
         f"util_binary={r['binary']:.3f};util_utility={r['utility']:.3f};"
         f"util_improvement={r['improvement']:.3f};"
         f"deferred={o['deferred_app_ticks']};"
         f"shed_capped={o['shed_capped_app_ticks']};"
         f"shed_churn={o['shed_churn_events']};"
         f"infeasible_admissions={o['infeasible_admissions']};"
         f"admit={adm.get('admit', 0)};defer={adm.get('defer', 0)};"
         f"reject={adm.get('reject', 0)};"
         f"within_budget={o['within_budget']['utility']}")
    comment(f"{sc.name} (overload): delivered utility {r['binary']:.3f} -> "
            f"{r['utility']:.3f} of oracle ({r['improvement']:.2f}x), "
            f"{o['deferred_app_ticks']} deferred app-ticks, "
            f"{o['shed_capped_app_ticks']} shed-capped app-ticks, "
            f"{o['infeasible_admissions']} infeasible admissions")
    RESULTS[sc.name] = rec
    return rec


def bench_chaos_scenario(sc, num_apps: int, ticks: int):
    """Chaos scenarios run the degraded/oracle/static triple instead of the
    plain pair: the record keys the gate pins are the ``chaos`` scorecard
    (zero unsafe moves, recovery to NORMAL, bounded degraded-vs-oracle
    ratio) plus the usual ``compare`` of degraded against static."""
    t0 = time.perf_counter()
    out = run_chaos_pair(sc)
    wall = time.perf_counter() - t0
    c = out["chaos"]
    rec = {
        "num_apps": num_apps,
        "pool": sc.max_apps,
        "ticks": ticks,
        "wall_s": wall,
        "baseline": out["baseline"].summary(),
        "degraded": out["degraded"].summary(),
        "oracle": out["oracle"].summary(),
        "compare": out["compare"],
        "chaos": c,
        "series": {"degraded": out["degraded"].series(),
                   "oracle": out["oracle"].series()},
    }
    dvo = c["degraded_vs_oracle"]
    emit(f"sim_scenarios/{sc.name}/N{num_apps}x{ticks}", wall * 1e6,
         f"viol_degraded={dvo['degraded']};viol_oracle={dvo['oracle']};"
         f"chaos_ratio={dvo['ratio']:.3f};unsafe_moves={c['unsafe_moves']};"
         f"degraded_ticks={c['degraded_ticks']};"
         f"modes={'+'.join(c['modes_entered'])};"
         f"breaker_trips={c['breaker_trips']};"
         f"quarantined={c['telemetry_quarantined']};"
         f"budget_overruns={c['budget_overruns']};"
         f"recovered={c['recovered']}")
    comment(f"{sc.name} (chaos): violation ticks degraded {dvo['degraded']} "
            f"vs oracle {dvo['oracle']} ({dvo['ratio']:.2f}x), "
            f"{c['unsafe_moves']} unsafe moves, modes entered "
            f"{c['modes_entered']}, recovered={c['recovered']}")
    RESULTS[sc.name] = rec
    return rec


def bench_scenario(name: str, num_apps: int, ticks: int, seed: int = 0):
    sc = get_scenario(name, num_apps=num_apps, ticks=ticks, seed=seed)
    if sc.netlat:
        return bench_netlat_scenario(sc, num_apps, ticks)
    if sc.overload:
        # Overload routing wins over chaos: overload_capacity_loss composes
        # both, and its acceptance story is the utility scorecard (the
        # chaos machinery still runs inside the utility-armed controller).
        return bench_overload_scenario(sc, num_apps, ticks)
    if sc.chaos:
        return bench_chaos_scenario(sc, num_apps, ticks)
    t0 = time.perf_counter()
    out = run_pair(sc)
    wall = time.perf_counter() - t0
    cmp = out["compare"]
    rec = {
        "num_apps": num_apps,
        "pool": sc.max_apps,
        "ticks": ticks,
        "wall_s": wall,
        "baseline": out["baseline"].summary(),
        "balanced": out["balanced"].summary(),
        "compare": cmp,
        "series": {"baseline": out["baseline"].series(),
                   "balanced": out["balanced"].series()},
    }
    viol = cmp["slo_violation_ticks"]
    move = cmp["movement"]

    def fmt(r):                      # ratio may be None (0-baseline)
        return "n/a" if r is None else f"{r:.3f}"

    emit(f"sim_scenarios/{name}/N{num_apps}x{ticks}", wall * 1e6,
         f"viol_baseline={viol['baseline']};viol_balanced={viol['balanced']};"
         f"viol_ratio={fmt(viol['ratio'])};"
         f"excess_ratio={fmt(cmp['over_ideal_excess_integral']['ratio'])};"
         f"moves={cmp['total_moves']};move_cost={move['cost']:.1f};"
         f"move_budget={move['budget']};within_budget={move['within_budget']};"
         f"rebalances={cmp['rebalances']};"
         f"solver_s={cmp['solver_time_s']:.2f}")
    comment(f"{name}: violation ticks {viol['baseline']} -> "
            f"{viol['balanced']} ({fmt(viol['ratio'])}x), "
            f"{cmp['rebalances']} rebalances moved {cmp['total_moves']} apps "
            f"(cost {move['cost']:.1f}"
            + (f" of budget {move['budget']:.0f}" if move["budget"] else "")
            + ")")
    RESULTS[name] = rec
    return rec


def bench_service_loop(num_apps: int, ticks: int):
    """PR 9 streaming service: the same trajectory as a lockstep run and as
    an event stream through ``ServiceLoop``, on the two curated plain
    scenarios the acceptance pins.  The record keys the gate pins are the
    ``compare`` scorecard (quality ratios vs lockstep, >= 30% fewer full
    cooperate passes, zero dropped events, zero delta reverts) plus the
    loop's operational ``stats`` (events/s, re-solve p50/p99)."""
    from repro.sim import run_service_pair

    section = {}
    for name in ("steady_diurnal", "flash_crowd"):
        sc = get_scenario(name, num_apps=num_apps, ticks=ticks)
        t0 = time.perf_counter()
        pair = run_service_pair(sc)
        wall = time.perf_counter() - t0
        cmp = pair["service_compare"]
        stats = pair["service"].extra["service"]
        section[name] = {
            "num_apps": num_apps,
            "ticks": ticks,
            "wall_s": wall,
            "compare": cmp,
            "stats": stats,
        }
        viol = cmp["slo_violation_ticks"]
        fp = cmp["full_passes"]
        emit(f"sim_scenarios/service_loop/{name}/N{num_apps}x{ticks}",
             wall * 1e6,
             f"viol_lockstep={viol['lockstep']};viol_service={viol['service']};"
             f"full_passes={fp['lockstep']}->{fp['service']};"
             f"reduction={fp['reduction']:.3f};"
             f"delta_solves={cmp['delta_solves']};"
             f"noop_ticks={cmp['noop_ticks']};"
             f"dropped={cmp['dropped_events']};"
             f"reverts={cmp['delta_reverts']};"
             f"events_per_s={stats['events_per_s']:.0f};"
             f"resolve_p50_ms={stats['resolve_p50_ms']:.1f};"
             f"resolve_p99_ms={stats['resolve_p99_ms']:.1f}")
        comment(f"{name} (service): full passes {fp['lockstep']} -> "
                f"{fp['service']} ({fp['reduction']:.0%} fewer), "
                f"{cmp['delta_solves']} delta solves, "
                f"{cmp['noop_ticks']} noop ticks, violations "
                f"{viol['lockstep']} -> {viol['service']}, "
                f"{cmp['dropped_events']} dropped events")
    RESULTS["service_loop"] = section
    return section


def run(smoke: bool = False):
    comment(f"--- fleet simulator scenarios "
            f"(XLA path, CPU{', smoke' if smoke else ''}) ---")
    num_apps, ticks = (128, 24) if smoke else (400, 160)
    for name in list_scenarios():
        bench_scenario(name, num_apps, ticks)
    bench_service_loop(num_apps, ticks)
    bench_service_ingest(num_apps, ticks)

    # Smoke numbers must not clobber the tracked fleet-scale record.
    name = "BENCH_sim_smoke.json" if smoke else "BENCH_sim.json"
    out_path = os.path.normpath(os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", name))
    with open(out_path, "w") as f:
        json.dump(RESULTS, f, indent=2, sort_keys=True)
    comment(f"wrote {out_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI (seconds, not minutes)")
    run(**vars(ap.parse_args()))
