"""CI perf-regression gate over the smoke benchmark records.

Compares the just-written ``BENCH_solver_smoke.json`` / ``BENCH_sim_smoke.json``
against the committed baselines (stashed by CI before the smoke runs) with
per-metric tolerances, and exits nonzero on any regression — the solver and
simulator scorecards become a gate instead of an artifact someone has to
remember to read.

Two tolerance regimes, deliberately different:

* **Machine-independent metrics** (violation-tick ratios, retrace and round
  counts, objectives, budget compliance) are pinned tightly — these are
  deterministic given the seeds, so drift means a behavior change.
* **Wall-clock metrics** (moves/s, cooperation total seconds) carry generous
  multipliers: the committed baseline and the CI runner are different
  machines, so only order-of-magnitude regressions are actionable.

Run what CI runs:

    PYTHONPATH=src python -m benchmarks.check_regression --baseline .bench-baseline

A missing baseline file skips that record (first run of a new benchmark); a
baseline metric missing from the current record is a regression — a renamed
metric must regenerate its committed baseline in the same PR.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import sys

SIM_SMOKE = "BENCH_sim_smoke.json"
SOLVER_SMOKE = "BENCH_solver_smoke.json"


@dataclasses.dataclass(frozen=True)
class Check:
    """One gated metric.

    ``rule`` is ``not_above`` (smaller is better: fail when
    ``cur > base * (1 + rel_slack) + abs_slack``), ``not_below`` (bigger is
    better: fail when ``cur < base / (1 + rel_slack) - abs_slack``), or
    ``stays_true`` (fail when the baseline is truthy and the current value
    is not).  ``path`` components may be ``"*"``, expanded against the
    baseline record.
    """

    file: str
    path: tuple
    rule: str
    abs_slack: float = 0.0
    rel_slack: float = 0.0


CHECKS = (
    # --- fleet simulator smoke: deterministic scorecards, tight slack ----
    Check(SIM_SMOKE, ("*", "compare", "slo_violation_ticks", "ratio"), "not_above", 0.10),
    Check(SIM_SMOKE, ("*", "compare", "over_ideal_excess_integral", "ratio"), "not_above", 0.15),
    Check(SIM_SMOKE, ("*", "compare", "movement", "within_budget"), "stays_true"),
    Check(SIM_SMOKE, ("*", "balanced", "over_capacity_tier_ticks"), "not_above", 2),
    Check(SIM_SMOKE, ("*", "balanced", "solver_retraces"), "not_above", 1),
    Check(SIM_SMOKE, ("*", "balanced", "workload_retraces"), "not_above", 1),
    Check(SIM_SMOKE, ("*", "balanced", "movement_cost"), "not_above", 10, 0.5),
    # Whole-scenario wall-clock: cross-machine, order-of-magnitude only.
    Check(SIM_SMOKE, ("*", "wall_s"), "not_above", 5.0, 3.0),
    # PR 5 pluggable-hierarchy scenario: the shard locality level must keep
    # paying for itself — the three-level controller stays ahead of static
    # on both the violation integral and shard co-location (explicit named
    # checks so a baseline regeneration that *dropped* the scenario, which
    # the wildcards would silently forgive, fails the gate).
    Check(
        SIM_SMOKE,
        ("shard_skew", "compare", "slo_violation_ticks", "ratio"),
        "not_above",
        0.05,
        0.10,
    ),
    Check(
        SIM_SMOKE,
        ("shard_skew", "compare", "shard_misplaced_app_ticks", "ratio"),
        "not_above",
        0.05,
        0.10,
    ),
    # PR 6 chaos family: the degraded-mode control plane's acceptance.
    # Containment is absolute — one unsafe move committed on faulted
    # telemetry is a bug, not drift to tolerate — and the controller must
    # come back to NORMAL once the fault window closes.
    Check(SIM_SMOKE, ("*", "chaos", "unsafe_moves"), "not_above", 0),
    Check(SIM_SMOKE, ("*", "chaos", "budget_overruns"), "not_above", 0),
    Check(SIM_SMOKE, ("*", "chaos", "recovered"), "stays_true"),
    # A chaos run that never left NORMAL proved nothing: residency in
    # degraded modes must stay in the baseline's ballpark.
    Check(SIM_SMOKE, ("*", "chaos", "degraded_ticks"), "not_below", 1, 0.5),
    # The price of flying blind, bounded per scenario (named checks so a
    # baseline regeneration that *dropped* a chaos scenario — which the
    # wildcards would silently forgive — fails the gate).
    Check(
        SIM_SMOKE,
        ("telemetry_blackout", "chaos", "degraded_vs_oracle", "ratio"),
        "not_above",
        0.5,
        0.25,
    ),
    Check(
        SIM_SMOKE,
        ("solver_brownout", "chaos", "degraded_vs_oracle", "ratio"),
        "not_above",
        0.5,
        0.25,
    ),
    Check(
        SIM_SMOKE,
        ("cascading_outage", "chaos", "degraded_vs_oracle", "ratio"),
        "not_above",
        0.5,
        0.25,
    ),
    # PR 7 overload family: the overload-resilient control plane's
    # acceptance.  Admission feasibility is absolute — one admitted app
    # that did not fit its priced tier is a bug, not drift — and the
    # utility-armed run must keep beating the binary baseline on delivered
    # utility (named per-scenario checks so a baseline regeneration that
    # dropped an overload scenario fails the gate).
    Check(SIM_SMOKE, ("*", "overload", "infeasible_admissions"), "not_above", 0),
    Check(SIM_SMOKE, ("*", "overload", "within_budget", "utility"), "stays_true"),
    Check(SIM_SMOKE, ("*", "overload", "within_budget", "binary"), "stays_true"),
    Check(SIM_SMOKE, ("*", "utility", "budget_overruns"), "not_above", 0),
    # Hysteresis is judged on churn: cap transitions must stay in the
    # baseline's ballpark, not flap per tick.
    Check(SIM_SMOKE, ("*", "overload", "shed_churn_events"), "not_above", 4, 0.5),
    Check(
        SIM_SMOKE,
        ("overload_surge", "overload", "delivered_utility_ratio", "improvement"),
        "not_below",
        0.02,
        0.05,
    ),
    Check(
        SIM_SMOKE,
        ("overload_flash", "overload", "delivered_utility_ratio", "improvement"),
        "not_below",
        0.05,
        0.10,
    ),
    Check(
        SIM_SMOKE,
        ("overload_capacity_loss", "overload", "delivered_utility_ratio", "improvement"),
        "not_below",
        0.05,
        0.10,
    ),
    # Graceful degradation must also be *strictly better than 1* on the
    # two pure-overload scenarios — not merely unchanged vs baseline.
    Check(
        SIM_SMOKE,
        ("overload_surge", "overload", "delivered_utility_ratio", "utility"),
        "not_below",
        0.02,
        0.03,
    ),
    Check(
        SIM_SMOKE,
        ("overload_flash", "overload", "delivered_utility_ratio", "utility"),
        "not_below",
        0.02,
        0.03,
    ),
    # PR 9 streaming service: event-driven control must match the lockstep
    # scorecard within tolerance while doing strictly less work.  Event
    # integrity and delta-solve safety are absolute — one dropped event or
    # one reverted delta is a bug, not drift — and the >= 30% full-pass
    # reduction is the acceptance number, pinned per scenario (named
    # checks so a baseline regeneration that dropped a scenario fails).
    Check(SIM_SMOKE, ("service_loop", "*", "compare", "dropped_events"), "not_above", 0),
    Check(SIM_SMOKE, ("service_loop", "*", "compare", "delta_reverts"), "not_above", 0),
    Check(
        SIM_SMOKE,
        ("service_loop", "*", "compare", "slo_violation_ticks", "ratio"),
        "not_above",
        0.10,
        0.25,
    ),
    Check(
        SIM_SMOKE,
        ("service_loop", "*", "compare", "mean_d2b", "ratio"),
        "not_above",
        0.15,
        0.25,
    ),
    Check(
        SIM_SMOKE,
        ("service_loop", "steady_diurnal", "compare", "full_passes", "reduction"),
        "not_below",
        0.03,
    ),
    Check(
        SIM_SMOKE,
        ("service_loop", "flash_crowd", "compare", "full_passes", "reduction"),
        "not_below",
        0.05,
        0.10,
    ),
    # PR 10 measured-latency family: the latency-SLO level's acceptance.
    # Budget compliance is absolute — one measured-stack move committed
    # into a tier over its live p99 budget is a bug, not drift — the
    # measurement plane must actually calibrate (an inert-fallback run
    # proves nothing), and the measured stack must keep beating the static
    # 36 ms constant on the p99-aware placement integral (named checks so
    # a baseline regeneration that dropped a network scenario — which the
    # wildcards would silently forgive — fails the gate).
    Check(SIM_SMOKE, ("*", "netlat", "budget_exceeding_moves", "measured"), "not_above", 0),
    Check(SIM_SMOKE, ("*", "netlat", "calibrated"), "stays_true"),
    Check(
        SIM_SMOKE,
        ("network_degraded_slow_links", "netlat", "network_p99_integral", "ratio"),
        "not_above",
        0.005,
        0.005,
    ),
    Check(
        SIM_SMOKE,
        ("network_degraded_asymmetric", "netlat", "network_p99_integral", "ratio"),
        "not_above",
        0.005,
        0.005,
    ),
    Check(
        SIM_SMOKE,
        ("network_degraded_jitter", "netlat", "network_p99_integral", "ratio"),
        "not_above",
        0.005,
        0.005,
    ),
    # PR 10 multi-producer ingestion: event integrity under submit-side
    # contention is absolute; sustained ingest rate is cross-machine, so
    # order-of-magnitude only.
    Check(SIM_SMOKE, ("service_ingest", "dropped_events"), "not_above", 0),
    Check(SIM_SMOKE, ("service_ingest", "per_app_ordered"), "stays_true"),
    Check(SIM_SMOKE, ("service_ingest", "ingest_events_per_s"), "not_below", 0, 3.0),
    # --- solver smoke: counts/objectives tight, wall-clock generous ------
    Check(SOLVER_SMOKE, ("local_search", "*", "batch16", "moves_per_s"), "not_below", 0, 3.0),
    Check(SOLVER_SMOKE, ("local_search", "*", "batch1", "moves_per_s"), "not_below", 0, 3.0),
    Check(SOLVER_SMOKE, ("local_search", "*", "batch16", "objective"), "not_above", 1e-3, 0.05),
    Check(SOLVER_SMOKE, ("cooperate", "*", "premask", "total_s"), "not_above", 0.05, 3.0),
    Check(SOLVER_SMOKE, ("cooperate", "*", "premask", "rounds"), "not_above", 2),
    Check(SOLVER_SMOKE, ("cooperate", "*", "premask", "host_side_frac"), "not_above", 0.15, 1.0),
    Check(SOLVER_SMOKE, ("cooperate", "*", "premask", "pack_retraces"), "not_above", 1),
    # The premask contract: the solver must never propose a region-infeasible
    # move, so the baseline (and the gate) pin this at exactly 0.
    Check(SOLVER_SMOKE, ("cooperate", "*", "premask", "region_rejections"), "not_above", 0),
    # PR 5 cooperation-bus overhead: the generic SchedulerLevel bus's own
    # routing glue (wall-clock belonging to no solver/level/feedback phase)
    # as a fraction of the pass — the protocol refactor must keep the
    # default two-level hot path within ~5% of phase-accounted time
    # (measured 1.00x pre- vs post-refactor wall-clock at N=10k locally).
    Check(
        SOLVER_SMOKE,
        ("cooperate", "*", "premask", "bus_overhead_frac"),
        "not_above",
        0.05,
        1.0,
    ),
    Check(SOLVER_SMOKE, ("cooperate", "*", "premask", "objective"), "not_above", 1e-3, 0.05),
    Check(SOLVER_SMOKE, ("cooperate", "*", "premask", "accepted"), "stays_true"),
    # Shape-bucketed jit caching: drifting sizes must keep sharing
    # executables (the PR 1 contract).
    Check(SOLVER_SMOKE, ("bucketing", "bucketed"), "not_above", 0),
    Check(SOLVER_SMOKE, ("move_eval", "*", "candidates_per_s"), "not_below", 0, 3.0),
    # PR 8 sharded fleet pass: a throughput floor (cross-machine, generous),
    # the zero-stranded-apps merge invariant (absolute — one valid app on an
    # infeasible tier after reassembly is a bug, not drift), and the
    # coordinator's share of the pass gated like the bus overhead.
    Check(SOLVER_SMOKE, ("shard_scale", "*", "apps_per_s"), "not_below", 0, 3.0),
    Check(SOLVER_SMOKE, ("shard_scale", "*", "stranded"), "not_above", 0),
    Check(
        SOLVER_SMOKE,
        ("shard_scale", "*", "coordinator_overhead_frac"),
        "not_above",
        0.05,
        1.0,
    ),
    Check(SOLVER_SMOKE, ("pallas_parity", "tier_agreement"), "not_below", 0.01),
    Check(SOLVER_SMOKE, ("pallas_parity", "rel_err"), "not_above", 1e-5, 9.0),
)


def _as_number(value, worst: float) -> float:
    """Ratios may be null in JSON (balanced > 0 while the baseline integral
    is 0) — null is the worst possible outcome for the metric's direction:
    +inf for smaller-is-better checks, -inf for bigger-is-better ones."""
    if value is None:
        return worst
    if isinstance(value, bool):
        return float(value)
    return float(value)


def _expand(record: dict, path: tuple) -> list[tuple]:
    """All concrete paths matching ``path`` in ``record`` (baseline-driven)."""
    paths = [()]
    node_for: dict = {(): record}
    for part in path:
        nxt = []
        for prefix in paths:
            node = node_for[prefix]
            if not isinstance(node, dict):
                continue
            keys = sorted(node) if part == "*" else ([part] if part in node else [])
            for key in keys:
                concrete = prefix + (key,)
                node_for[concrete] = node[key]
                nxt.append(concrete)
        paths = nxt
    return paths


def _lookup(record: dict, path: tuple):
    node = record
    for part in path:
        if not isinstance(node, dict) or part not in node:
            return KeyError
        node = node[part]
    return node


def run_checks(baselines: dict, currents: dict) -> tuple[list[str], list[str]]:
    """Returns (passed, regressions) as printable lines."""
    passed: list[str] = []
    regressions: list[str] = []
    for check in CHECKS:
        base_rec = baselines.get(check.file)
        cur_rec = currents.get(check.file)
        if base_rec is None:
            continue
        paths = _expand(base_rec, check.path)
        if not paths:
            # A check that matches nothing would silently un-gate itself —
            # the likely cause is a metric renamed and regenerated into the
            # baselines without updating CHECKS.
            regressions.append(
                f"{check.file}:{'/'.join(check.path)}: check matched no baseline metrics"
            )
            continue
        for path in paths:
            name = f"{check.file}:{'/'.join(map(str, path))}"
            base_val = _lookup(base_rec, path)
            cur_val = _lookup(cur_rec, path) if cur_rec is not None else KeyError
            if cur_val is KeyError:
                regressions.append(f"{name}: metric missing from current record")
                continue
            if check.rule == "stays_true":
                if base_val and not cur_val:
                    regressions.append(f"{name}: was {base_val!r}, now {cur_val!r}")
                else:
                    passed.append(f"{name}: {cur_val!r}")
                continue
            worst = math.inf if check.rule == "not_above" else -math.inf
            base_num = _as_number(base_val, worst)
            cur_num = _as_number(cur_val, worst)
            if check.rule == "not_above":
                limit = base_num * (1.0 + check.rel_slack) + check.abs_slack
                ok = cur_num <= limit
                op = "<="
            else:
                limit = base_num / (1.0 + check.rel_slack) - check.abs_slack
                ok = cur_num >= limit
                op = ">="
            line = f"{name}: {cur_num:.6g} {op} {limit:.6g} (baseline {base_num:.6g})"
            (passed if ok else regressions).append(line)
    return passed, regressions


def _load_records(directory: str) -> dict:
    records = {}
    for name in (SIM_SMOKE, SOLVER_SMOKE):
        path = os.path.join(directory, name)
        if os.path.exists(path):
            with open(path) as f:
                records[name] = json.load(f)
    return records


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    parser.add_argument(
        "--baseline",
        required=True,
        help="directory holding the committed BENCH_*_smoke.json baselines",
    )
    parser.add_argument(
        "--current",
        default=".",
        help="directory holding the just-written smoke records (default: repo root)",
    )
    args = parser.parse_args(argv)

    baselines = _load_records(args.baseline)
    currents = _load_records(args.current)
    if not baselines:
        print(f"# no baselines under {args.baseline}; nothing to gate")
        return 0
    for name in (SIM_SMOKE, SOLVER_SMOKE):
        if name in baselines and name not in currents:
            print(f"REGRESSION {name}: current record missing from {args.current}")
            return 1

    passed, regressions = run_checks(baselines, currents)
    for line in passed:
        print(f"ok {line}")
    for line in regressions:
        print(f"REGRESSION {line}")
    print(f"# {len(passed)} checks passed, {len(regressions)} regressions")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
