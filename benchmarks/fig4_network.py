"""Paper Fig. 4: worst-case (p99) network latency across SPTLB integration
variants (no_cnst / w_cnst / manual_cnst) x solver engine (local/optimal) x
timeout knob.

Claim under test: w_cnst almost always best on latency; no_cnst worst;
manual_cnst the middle ground that sometimes beats w_cnst.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import TIMEOUTS, comment, emit, load_cluster
from repro.core import CoopConfig, Sptlb


def run(num_apps: int = 1200, timeouts=TIMEOUTS):
    cluster = load_cluster(num_apps)
    s = Sptlb(cluster)
    # warm the jit caches so timings reflect solve time, not compilation
    s.balance("local", timeout_s=30, config=CoopConfig(variant="no_cnst"))
    s.balance("optimal", timeout_s=30, config=CoopConfig(variant="no_cnst"))
    rows = []
    for engine in ("local", "optimal"):
        for timeout_s in timeouts:
            for variant in ("no_cnst", "w_cnst", "manual_cnst"):
                t0 = time.perf_counter()
                d = s.balance(engine, timeout_s=timeout_s,
                              config=CoopConfig(variant=variant,
                                                max_rounds=20))
                dt = time.perf_counter() - t0
                rows.append((engine, timeout_s, variant, d.network_p99_ms,
                             dt, d.difference_to_balance))
                emit(f"fig4/{engine}/{timeout_s}s/{variant}", dt * 1e6,
                     f"net_p99_ms={d.network_p99_ms:.0f};"
                     f"d2b={d.difference_to_balance:.3f};"
                     f"feasible={d.violations.ok}")

    comment("--- Fig 4: p99 network latency (ms) by variant ---")
    comment(f"{'engine':8s} {'timeout':8s} {'no_cnst':>8s} {'w_cnst':>8s} "
            f"{'manual':>8s}")
    by_key = {}
    for engine, ts, variant, p99, dt, d2b in rows:
        by_key.setdefault((engine, ts), {})[variant] = p99
    for (engine, ts), vals in by_key.items():
        comment(f"{engine:8s} {ts:<8d} {vals['no_cnst']:8.0f} "
                f"{vals['w_cnst']:8.0f} {vals['manual_cnst']:8.0f}")

    # --- paper-claim checks (aggregated over engines/timeouts) ---
    no = np.array([r[3] for r in rows if r[2] == "no_cnst"])
    w = np.array([r[3] for r in rows if r[2] == "w_cnst"])
    man = np.array([r[3] for r in rows if r[2] == "manual_cnst"])
    claims = [
        ("no_cnst has the worst p99 latency (mean)",
         no.mean() > w.mean() and no.mean() > man.mean()),
        ("w_cnst improves tail latency over no_cnst",
         w.mean() < no.mean()),
        ("manual_cnst matches or beats w_cnst on tail latency",
         man.mean() <= w.mean() * 1.1),
    ]
    for text, ok in claims:
        comment(f"CLAIM [{'PASS' if ok else 'FAIL'}]: {text}")
    comment("NOTE vs paper: the paper found w_cnst almost always best on "
            "latency with manual_cnst a close middle ground; under our "
            "synthetic ring geography manual_cnst is strictly best, because "
            "per-app accept/reject feedback bounds every placement while "
            "tier-level region-overlap constraints cannot see app data "
            "regions.  This strengthens the paper's conclusion that the "
            "feedback co-operation is the right integration point.")
    return rows, claims


if __name__ == "__main__":
    run()
