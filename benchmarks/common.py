"""Shared benchmark plumbing: workload construction, timing, CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (plus human-
readable tables to stderr-style comment lines prefixed with '#').
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import generate_cluster

# Paper §4 experiment scale stand-in: live Meta tier data is proprietary;
# this is the calibrated synthetic workload (5 tiers, paper SLO table,
# tier 3 hot — see core/telemetry.py).
NUM_APPS = 1200
SEED = 0

# Paper timeout knobs (seconds) -> deterministic iteration budgets
TIMEOUTS = (30, 60, 600)


def load_cluster(num_apps: int = NUM_APPS, seed: int = SEED):
    return generate_cluster(num_apps=num_apps, seed=seed)


def random_problem_arrays(N: int, T: int, seed: int = 0):
    """Flat random arrays in the move_eval kernel signature order.

    Shared by the solver benchmarks and the kernel parity tests (tests must
    not be imported by benchmarks, so the builder lives here).
    """
    rng = np.random.default_rng(seed)
    demand = jnp.asarray(rng.lognormal(1, 0.8, (N, 2)), jnp.float32)
    tasks = jnp.asarray(rng.integers(1, 40, N), jnp.float32)
    crit = jnp.asarray(rng.random(N), jnp.float32)
    x = jnp.asarray(rng.integers(0, T, N), jnp.int32)
    x0 = jnp.asarray(rng.integers(0, T, N), jnp.int32)
    cap = jnp.asarray(rng.uniform(400, 900, (T, 2)), jnp.float32)
    klim = jnp.asarray(rng.uniform(800, 2000, T), jnp.float32)
    ideal = jnp.full((T, 2), 0.7, jnp.float32)
    ideal_t = jnp.full((T,), 0.8, jnp.float32)
    util = jax.ops.segment_sum(demand, x, num_segments=T)
    ttasks = jax.ops.segment_sum(tasks, x, num_segments=T)
    w = jnp.asarray([1e4, 1e3, 1e2, 1e1, 1e0], jnp.float32)
    return (demand, tasks, crit, x, x0, cap, klim, ideal, ideal_t,
            util, ttasks, w)


def emit(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def comment(text: str):
    print(f"# {text}")
    sys.stdout.flush()


def timeit(fn, *args, warmup: int = 1, reps: int = 3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    return out, float(np.median(times)) * 1e6
