"""Shared benchmark plumbing: workload construction, timing, CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (plus human-
readable tables to stderr-style comment lines prefixed with '#').
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import generate_cluster

# Paper §4 experiment scale stand-in: live Meta tier data is proprietary;
# this is the calibrated synthetic workload (5 tiers, paper SLO table,
# tier 3 hot — see core/telemetry.py).
NUM_APPS = 1200
SEED = 0

# Paper timeout knobs (seconds) -> deterministic iteration budgets
TIMEOUTS = (30, 60, 600)


def load_cluster(num_apps: int = NUM_APPS, seed: int = SEED):
    return generate_cluster(num_apps=num_apps, seed=seed)


def emit(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def comment(text: str):
    print(f"# {text}")
    sys.stdout.flush()


def timeit(fn, *args, warmup: int = 1, reps: int = 3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    return out, float(np.median(times)) * 1e6
