"""Benchmark entry point: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (comment lines start with '#').

  fig3  — SPTLB vs greedy, 3 objectives       (paper Fig. 3 a/b/c)
  fig4  — network p99 across integrations     (paper Fig. 4)
  fig5  — pareto: balance vs solve time       (paper Fig. 5)
  solver_scale — scheduler hot-spot scaling   (supporting)
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "fig3", "fig4", "fig5", "solver_scale"])
    ap.add_argument("--num-apps", type=int, default=1200)
    ap.add_argument("--fast", action="store_true",
                    help="30s-timeout budgets only (CI-friendly)")
    args = ap.parse_args()

    timeouts = (30,) if args.fast else (30, 60, 600)

    from benchmarks import fig3_balance, fig4_network, fig5_pareto, solver_scale
    from benchmarks.common import comment

    t0 = time.time()
    print("name,us_per_call,derived")
    if args.only in (None, "fig3"):
        fig3_balance.run(args.num_apps)
    if args.only in (None, "fig4"):
        fig4_network.run(args.num_apps, timeouts=timeouts)
    if args.only in (None, "fig5"):
        fig5_pareto.run(args.num_apps, timeouts=timeouts)
    if args.only in (None, "solver_scale"):
        solver_scale.run()
    comment(f"total benchmark time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
