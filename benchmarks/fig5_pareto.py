"""Paper Fig. 5: Pareto frontier over (difference-to-balanced-state, solve
time) for the three integration variants.

Claim under test: manual_cnst points form the Pareto frontier — best
solution quality in the least time; w_cnst much worse in both because of its
added constraint complexity.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import TIMEOUTS, comment, emit, load_cluster
from repro.core import CoopConfig, Sptlb


def pareto_front(points):
    """points: list of (x=time, y=d2b, label).  Returns frontier labels."""
    front = []
    for i, (xi, yi, li) in enumerate(points):
        dominated = any(
            (xj <= xi and yj <= yi and (xj < xi or yj < yi))
            for j, (xj, yj, lj) in enumerate(points) if j != i)
        if not dominated:
            front.append(li)
    return front


def run(num_apps: int = 1200, timeouts=TIMEOUTS):
    cluster = load_cluster(num_apps)
    s = Sptlb(cluster)
    # warm the jit caches so timings reflect solve time, not compilation
    s.balance("local", timeout_s=30, config=CoopConfig(variant="no_cnst"))
    s.balance("optimal", timeout_s=30, config=CoopConfig(variant="no_cnst"))
    points = []        # (time, d2b, label)
    points3 = []       # (time, d2b, net_p99, label)
    for engine in ("local", "optimal"):
        for timeout_s in timeouts:
            for variant in ("no_cnst", "w_cnst", "manual_cnst"):
                t0 = time.perf_counter()
                d = s.balance(engine, timeout_s=timeout_s,
                              config=CoopConfig(variant=variant,
                                                max_rounds=20))
                dt = time.perf_counter() - t0
                label = f"{variant}/{engine}/{timeout_s}s"
                points.append((dt, d.difference_to_balance, label))
                points3.append((dt, d.difference_to_balance,
                                d.network_p99_ms, label))
                emit(f"fig5/{label}", dt * 1e6,
                     f"d2b={d.difference_to_balance:.3f};time_s={dt:.2f};"
                     f"net_p99={d.network_p99_ms:.0f}")

    front = pareto_front(points)
    comment("--- Fig 5: (solve time s, difference-to-balance, net p99) ---")
    for dt, d2b, p99, label in sorted(points3, key=lambda p: p[0]):
        star = " *2d-frontier*" if label in front else ""
        comment(f"{label:28s} time={dt:7.2f}s d2b={d2b:.3f} "
                f"p99={p99:3.0f}ms{star}")

    # 3D non-domination over (time, d2b, net_p99) — the paper's actual
    # "ideal co-operation" claim once network cost is part of the picture.
    def dominated3(i):
        xi, yi, zi, _ = points3[i]
        return any(xj <= xi and yj <= yi and zj <= zi
                   and (xj < xi or yj < yi or zj < zi)
                   for j, (xj, yj, zj, _) in enumerate(points3) if j != i)
    front3 = [points3[i][3] for i in range(len(points3)) if not dominated3(i)]
    manual3 = [lab for lab in front3 if lab.startswith("manual")]

    claims = [
        ("manual_cnst is Pareto-optimal over (time, balance, net latency)",
         len(manual3) > 0),
        ("w_cnst does not dominate the frontier",
         sum(1 for lab in front if lab.startswith("w_cnst")) <= len(front) / 2),
        ("manual_cnst dominates w_cnst on balance (mean)",
         np.mean([p[1] for p in points if p[2].startswith("manual")])
         <= np.mean([p[1] for p in points if p[2].startswith("w_cnst")])),
    ]
    for text, ok in claims:
        comment(f"CLAIM [{'PASS' if ok else 'FAIL'}]: {text}")
    comment("NOTE vs paper: Fig 5's 2D (time, balance) frontier put "
            "manual_cnst strictly first because Meta's solver runs to its "
            "timeout, so extra constraints *reduced* solve time.  Our "
            "LocalSearch converges in milliseconds, so manual_cnst's extra "
            "feedback rounds cost relatively more time and no_cnst wins the "
            "2D frontier; in the full (time, balance, latency) space "
            "manual_cnst remains the non-dominated co-operation point — the "
            "paper's conclusion.")
    return points, front, claims


if __name__ == "__main__":
    run()
